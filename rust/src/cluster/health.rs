//! Backend health checking: periodic `stats` probes with timeout,
//! mark-down/mark-up, and exponential probe backoff for dead backends.
//!
//! One monitor thread owns every backend's health verdict. Each probe is a
//! short-lived connection issuing `{"cmd":"stats"}` and waiting (bounded)
//! for the reply — exercising the full accept → parse → scrape path, so a
//! process that is alive but wedged fails the probe too. A successful
//! probe (re)establishes the backend's pooled pipelined connection before
//! marking it up, so routed traffic always has somewhere to go the moment
//! the verdict flips. A failed probe marks the backend down immediately —
//! abandoning its pooled connection answers every pending reply with a
//! retryable `overloaded` line (sampled requests' proxy-side timelines
//! are still committed, with their upstream wait noted `abandoned`, so a
//! trace query shows where in-flight work died) — and doubles the probe
//! interval up to `max_backoff` so a long-dead backend is not hammered.
//!
//! Routing reacts through [`crate::cluster::ring::HashRing::route_where`]:
//! keys owned by a down backend deterministically fail over to the next
//! live member and return home on mark-up (minimal remapping both ways).

use crate::cluster::backend::Backend;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Probe cadence and bounds.
#[derive(Clone, Debug)]
pub struct HealthPolicy {
    /// Probe interval for healthy backends (and the backoff floor).
    pub interval: Duration,
    /// Per-probe connect + reply timeout.
    pub timeout: Duration,
    /// Backoff ceiling for dead backends.
    pub max_backoff: Duration,
}

impl Default for HealthPolicy {
    fn default() -> Self {
        HealthPolicy {
            interval: Duration::from_millis(500),
            timeout: Duration::from_secs(2),
            max_backoff: Duration::from_secs(8),
        }
    }
}

/// Run the monitor until `stop` is set: probe each backend on its own
/// schedule, mark up/down, and back off on failures. Blocks — the proxy
/// runs it on a dedicated thread.
pub fn health_loop(backends: &[Arc<Backend>], policy: &HealthPolicy, stop: &AtomicBool) {
    let interval = policy.interval.max(Duration::from_millis(10));
    let mut next = vec![Instant::now(); backends.len()];
    let mut backoff = vec![interval; backends.len()];
    while !stop.load(Ordering::Acquire) {
        let now = Instant::now();
        for (i, backend) in backends.iter().enumerate() {
            if now < next[i] {
                continue;
            }
            if backend.fetch_stats().is_some() && backend.ensure_connected() {
                let was_down = !backend.is_healthy();
                backend.mark_up();
                if was_down {
                    println!(
                        "dither-proxy: backend {} ({}) is up",
                        backend.id(),
                        backend.addr()
                    );
                }
                backoff[i] = interval;
                next[i] = now + interval;
            } else {
                let was_up = backend.is_healthy();
                backend.mark_down();
                if was_up {
                    println!(
                        "dither-proxy: backend {} ({}) marked down",
                        backend.id(),
                        backend.addr()
                    );
                }
                next[i] = now + backoff[i];
                backoff[i] = backoff[i].saturating_mul(2).min(policy.max_backoff.max(interval));
            }
        }
        std::thread::sleep(Duration::from_millis(20).min(interval));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_policy_is_sane() {
        let p = HealthPolicy::default();
        assert!(p.interval < p.max_backoff);
        assert!(p.timeout >= p.interval);
    }

    #[test]
    fn dead_backends_are_marked_down_with_backoff() {
        // Nothing listens on the address: the first sweep probes (and
        // fails) every backend, later sweeps respect the growing backoff.
        let stop = Arc::new(AtomicBool::new(false));
        let backends: Vec<Arc<Backend>> = (0..2)
            .map(|i| {
                Arc::new(Backend::new(
                    i,
                    "127.0.0.1:1".to_string(),
                    4,
                    Duration::from_millis(50),
                    stop.clone(),
                    Arc::new(crate::trace::Tracer::new(crate::trace::TraceConfig::default())),
                ))
            })
            .collect();
        let policy = HealthPolicy {
            interval: Duration::from_millis(20),
            timeout: Duration::from_millis(50),
            max_backoff: Duration::from_millis(100),
        };
        let stop2 = stop.clone();
        let list = backends.clone();
        let monitor = std::thread::spawn(move || health_loop(&list, &policy, &stop2));
        std::thread::sleep(Duration::from_millis(150));
        stop.store(true, Ordering::Release);
        monitor.join().unwrap();
        for b in &backends {
            assert!(!b.is_healthy(), "unreachable backend must stay down");
        }
    }
}
