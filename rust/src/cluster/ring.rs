//! Consistent-hash ring with virtual nodes: the cluster front tier's
//! routing table.
//!
//! Each member (backend server process) owns `replicas` *virtual nodes* —
//! pseudo-random points on a `u64` ring. A key routes to the member owning
//! the first point clockwise from the key's hash. Virtual nodes smooth the
//! per-member load (the classic consistent-hashing construction), and the
//! construction gives **minimal remapping**: adding a member moves only
//! the keys that now land on the new member's points, removing one moves
//! only the removed member's keys — every other key keeps its owner. The
//! same walk-clockwise rule yields deterministic re-routing around dead
//! members ([`HashRing::route_where`]): a key whose owner is down always
//! lands on the same next-alive member, so two proxy replicas agree
//! without coordination.
//!
//! Point positions depend only on `(member id, replica index)` — never on
//! insertion order — so rings built by different processes from the same
//! membership are identical.

use crate::util::rng::counter_hash;
use std::collections::BTreeSet;

/// Default virtual nodes per member: enough that a 2–16 member ring
/// balances within a few tens of percent, cheap enough that membership
/// changes stay trivial.
pub const DEFAULT_REPLICAS: usize = 64;

/// Salt for ring point placement (distinct from every other hash stream
/// in the crate).
const POINT_SALT: u64 = 0x5249_4E47_7C9B_55D1;

/// Salt for key hashing.
const KEY_SALT: u64 = 0x4B45_597C_0D17_E881;

/// Stable 64-bit hash of a routing key (FNV-1a folded through the
/// SplitMix64 finalizer so short keys still spread over the whole ring).
pub fn key_hash(key: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in key.as_bytes() {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    counter_hash(KEY_SALT, h)
}

/// The position of one virtual node.
fn point(member: usize, replica: usize) -> u64 {
    counter_hash(counter_hash(POINT_SALT, member as u64 + 1), replica as u64)
}

/// A consistent-hash ring over `usize` member ids.
#[derive(Clone, Debug)]
pub struct HashRing {
    /// Virtual nodes per member.
    replicas: usize,
    /// Ring points, sorted by position: `(position, member)`.
    points: Vec<(u64, usize)>,
    /// Current membership.
    members: BTreeSet<usize>,
}

impl HashRing {
    /// Empty ring with `replicas` virtual nodes per member (min 1).
    pub fn new(replicas: usize) -> HashRing {
        HashRing {
            replicas: replicas.max(1),
            points: Vec::new(),
            members: BTreeSet::new(),
        }
    }

    /// Ring over members `0..n` (the proxy's static backend list).
    pub fn with_members(replicas: usize, n: usize) -> HashRing {
        let mut ring = HashRing::new(replicas);
        for id in 0..n {
            ring.add(id);
        }
        ring
    }

    /// Add a member (no-op if present). Only keys whose successor point
    /// now belongs to `id` move; every other key keeps its owner.
    pub fn add(&mut self, id: usize) {
        if !self.members.insert(id) {
            return;
        }
        for r in 0..self.replicas {
            let p = (point(id, r), id);
            let at = self.points.partition_point(|q| *q < p);
            self.points.insert(at, p);
        }
    }

    /// Remove a member (no-op if absent). Only the removed member's keys
    /// move — each to the next point clockwise.
    pub fn remove(&mut self, id: usize) {
        if self.members.remove(&id) {
            self.points.retain(|&(_, m)| m != id);
        }
    }

    /// Number of members.
    pub fn len(&self) -> usize {
        self.members.len()
    }

    /// True when the ring has no members.
    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    /// True when `id` is a member.
    pub fn contains(&self, id: usize) -> bool {
        self.members.contains(&id)
    }

    /// The member owning `key`: the first ring point clockwise from the
    /// key's hash. `None` on an empty ring — the caller must surface that
    /// as an error, there is nowhere to route.
    pub fn route(&self, key: &str) -> Option<usize> {
        self.route_where(key, |_| true)
    }

    /// [`HashRing::route`] restricted to members `alive` accepts: walks
    /// clockwise from the key's point, probing each *distinct* member in
    /// ring order until one is alive. Keys owned by live members are
    /// untouched by other members' deaths, and a dead owner's keys always
    /// fail over to the same successor (deterministic re-routing).
    pub fn route_where(&self, key: &str, alive: impl Fn(usize) -> bool) -> Option<usize> {
        if self.points.is_empty() {
            return None;
        }
        let h = key_hash(key);
        let start = self.points.partition_point(|&(p, _)| p < h);
        let n = self.points.len();
        let mut tried: Vec<usize> = Vec::new();
        for off in 0..n {
            let (_, member) = self.points[(start + off) % n];
            if tried.contains(&member) {
                continue;
            }
            if alive(member) {
                return Some(member);
            }
            tried.push(member);
            if tried.len() == self.members.len() {
                break; // every member probed and down
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn keys(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("model-{}/k={}", i % 7, i)).collect()
    }

    #[test]
    fn empty_ring_routes_nowhere() {
        let ring = HashRing::new(64);
        assert!(ring.is_empty());
        assert_eq!(ring.route("digits_linear/k=4"), None);
        assert_eq!(ring.route_where("x", |_| true), None);
    }

    #[test]
    fn routing_is_deterministic_and_membership_independent_of_order() {
        let a = HashRing::with_members(32, 4);
        let mut b = HashRing::new(32);
        for id in [3, 0, 2, 1] {
            b.add(id);
        }
        for k in keys(200) {
            assert_eq!(a.route(&k), b.route(&k), "insertion order must not matter");
        }
    }

    #[test]
    fn all_members_own_keys() {
        let ring = HashRing::with_members(64, 4);
        let mut hit = [false; 4];
        for k in keys(1000) {
            hit[ring.route(&k).unwrap()] = true;
        }
        assert!(hit.iter().all(|&h| h), "4 members must all own keys: {hit:?}");
    }

    #[test]
    fn remove_then_add_restores_routing() {
        let mut ring = HashRing::with_members(64, 3);
        let before: Vec<_> = keys(500).iter().map(|k| ring.route(k)).collect();
        ring.remove(1);
        assert_eq!(ring.len(), 2);
        assert!(!ring.contains(1));
        ring.add(1);
        let after: Vec<_> = keys(500).iter().map(|k| ring.route(k)).collect();
        assert_eq!(before, after, "points depend only on (member, replica)");
    }

    #[test]
    fn route_where_fails_over_deterministically() {
        let ring = HashRing::with_members(64, 3);
        for k in keys(300) {
            let owner = ring.route(&k).unwrap();
            // Owner alive: exclusion of others never moves the key.
            assert_eq!(ring.route_where(&k, |m| m == owner), Some(owner));
            // Owner dead: the key fails over, and always to the same member.
            let f1 = ring.route_where(&k, |m| m != owner).unwrap();
            let f2 = ring.route_where(&k, |m| m != owner).unwrap();
            assert_ne!(f1, owner);
            assert_eq!(f1, f2);
        }
        // Everyone dead: nowhere to route.
        assert_eq!(ring.route_where("k", |_| false), None);
    }
}
