//! Proxy-side handle to one upstream `serve` process: a pooled pipelined
//! connection, an in-flight window, and the pending-reply table that tags
//! out-of-order upstream completions back to the originating client.
//!
//! The proxy speaks the PR 4 pipelined protocol upstream: one persistent
//! connection per backend carries every forwarded request, each rewritten
//! to a proxy-unique upstream id before the send. A dedicated reader
//! thread drains completions in whatever order the backend finishes them,
//! looks each id up in the pending table, rewrites the id back to the
//! client's original one and hands the line to that client connection's
//! writer channel. The window (`min(configured, advertised max_inflight)`)
//! bounds what this proxy keeps outstanding per backend; submissions
//! beyond it are refused with [`ForwardError::Busy`] so the backpressure
//! propagates to the client as an `overloaded` reply.
//!
//! Connection loss is failure-atomic per request: every pending reply is
//! answered with a retryable `overloaded` line (the upstream id was never
//! answered, so the client must retry; inference is idempotent under every
//! scheme), the backend is marked down, and the health monitor
//! ([`crate::cluster::health`]) reconnects with backoff.

use crate::coordinator::protocol::{
    format_overloaded, format_trace_query, parse_hello, parse_stats, parse_traces, response_id,
    HelloInfo, StatsSummary, TraceQuery,
};
use crate::trace::{Stage, Trace, TraceBuilder, Tracer};
use crate::util::json::Json;
use crate::util::threadpool::WorkerPool;
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Why a forward was refused. The caller answers the client itself (the
/// request was never submitted upstream, so no reply will arrive).
#[derive(Debug, PartialEq, Eq)]
pub enum ForwardError {
    /// The backend's in-flight window is full — backpressure; the client
    /// should back off and retry.
    Busy,
    /// The backend is down or its pooled connection is gone.
    Down,
}

/// Where one forwarded request's reply goes: the originating client
/// connection's writer channel, plus the id the client used (upstream
/// replies carry the proxy's rewritten id and are mapped back).
struct Route {
    client_id: u64,
    tx: Sender<String>,
    /// Sampled requests carry their proxy-side trace builder with the
    /// submission instant; the reader stamps [`Stage::UpstreamWait`]
    /// (submit → completion) and commits the trace on reply arrival.
    trace: Option<(Box<TraceBuilder>, Instant)>,
}

/// The live pooled connection: the write half plus the negotiated window.
struct Upstream {
    writer: TcpStream,
    window: usize,
}

/// One upstream `serve` process as seen by the proxy.
pub struct Backend {
    id: usize,
    addr: String,
    /// Configured per-backend window cap (the handshake may lower it).
    cap: usize,
    io_timeout: Duration,
    /// Health verdict, owned by the health monitor.
    healthy: AtomicBool,
    /// Forwarded-but-unanswered requests on the pooled connection.
    inflight: AtomicUsize,
    /// Proxy-unique upstream request ids.
    next_id: AtomicU64,
    conn: Mutex<Option<Upstream>>,
    /// Bumped per (re)connect; a reader whose epoch is stale exits
    /// without touching state that now belongs to a newer connection.
    epoch: AtomicU64,
    pending: Mutex<HashMap<u64, Route>>,
    /// Rounding schemes the backend advertised in its last `hello`
    /// handshake (empty until the first successful connect; a v1 backend
    /// defaults to the paper's trio via [`parse_hello`]).
    schemes: Mutex<Vec<String>>,
    readers: Mutex<WorkerPool>,
    /// Proxy-wide stop flag (readers poll it between read timeouts).
    stop: Arc<AtomicBool>,
    /// The proxy's shared tracer: every backend commits its finished
    /// proxy-side timelines into the same ring.
    tracer: Arc<Tracer>,
    // Scrape counters.
    forwarded: AtomicU64,
    reconnects: AtomicU64,
    lost: AtomicU64,
}

impl Backend {
    /// Handle for the backend at `addr`, initially down (the health
    /// monitor probes it up). `cap` bounds the in-flight window; `tracer`
    /// is the proxy-wide ring that finished proxy-side timelines land in.
    pub fn new(
        id: usize,
        addr: String,
        cap: usize,
        io_timeout: Duration,
        stop: Arc<AtomicBool>,
        tracer: Arc<Tracer>,
    ) -> Backend {
        Backend {
            id,
            addr,
            cap: cap.max(1),
            io_timeout,
            healthy: AtomicBool::new(false),
            inflight: AtomicUsize::new(0),
            next_id: AtomicU64::new(0),
            conn: Mutex::new(None),
            epoch: AtomicU64::new(0),
            pending: Mutex::new(HashMap::new()),
            schemes: Mutex::new(Vec::new()),
            readers: Mutex::new(WorkerPool::new()),
            stop,
            tracer,
            forwarded: AtomicU64::new(0),
            reconnects: AtomicU64::new(0),
            lost: AtomicU64::new(0),
        }
    }

    /// Backend index (its hash-ring member id).
    pub fn id(&self) -> usize {
        self.id
    }

    /// Configured in-flight window cap (the live window may be lower if
    /// the backend advertised a smaller `max_inflight`).
    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Upstream address.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Current health verdict.
    pub fn is_healthy(&self) -> bool {
        self.healthy.load(Ordering::Acquire)
    }

    /// Requests forwarded upstream over the backend's lifetime.
    pub fn forwarded(&self) -> u64 {
        self.forwarded.load(Ordering::Relaxed)
    }

    /// Times the pooled connection was (re)established.
    pub fn reconnects(&self) -> u64 {
        self.reconnects.load(Ordering::Relaxed)
    }

    /// Pending replies abandoned to connection loss (each was answered
    /// with a retryable `overloaded` line).
    pub fn lost(&self) -> u64 {
        self.lost.load(Ordering::Relaxed)
    }

    /// Forwarded-but-unanswered requests right now.
    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Acquire)
    }

    /// Rounding schemes the backend advertised on its last handshake
    /// (empty before the first successful connect).
    pub fn schemes(&self) -> Vec<String> {
        self.schemes.lock().unwrap().clone()
    }

    /// Mark the backend serviceable (health monitor, after a successful
    /// probe with the pooled connection up).
    pub fn mark_up(&self) {
        self.healthy.store(true, Ordering::Release);
    }

    /// Mark the backend down and abandon the pooled connection: every
    /// pending reply is answered with a retryable `overloaded` line so no
    /// client waits on a dead process.
    pub fn mark_down(&self) {
        self.abandon(self.conn.lock().unwrap());
    }

    /// Forward one inference request. `req` is the client's parsed request
    /// line; its `id` is rewritten to a proxy-unique upstream id before
    /// the send and the original `client_id` is recorded so the reader can
    /// tag the completion back. `reply` is the client connection's writer
    /// channel. `trace` is the request's proxy-side trace builder (if
    /// sampled): a successful submit takes it into the pending table so
    /// the reader can close the timeline; on refusal it stays with the
    /// caller for fail-over or commit.
    pub fn forward(
        &self,
        req: &Json,
        client_id: u64,
        reply: &Sender<String>,
        trace: &mut Option<Box<TraceBuilder>>,
    ) -> Result<(), ForwardError> {
        if !self.is_healthy() {
            return Err(ForwardError::Down);
        }
        let mut conn = self.conn.lock().unwrap();
        let Some(up) = conn.as_mut() else {
            return Err(ForwardError::Down);
        };
        // Optimistic window claim: racing submitters cannot overshoot.
        if self.inflight.fetch_add(1, Ordering::AcqRel) >= up.window {
            self.inflight.fetch_sub(1, Ordering::AcqRel);
            return Err(ForwardError::Busy);
        }
        let upstream_id = self.next_id.fetch_add(1, Ordering::Relaxed) + 1;
        let submitted = trace.take().map(|b| (b, Instant::now()));
        self.pending.lock().unwrap().insert(
            upstream_id,
            Route {
                client_id,
                tx: reply.clone(),
                trace: submitted,
            },
        );
        let mut line = req.clone();
        if let Json::Obj(fields) = &mut line {
            fields.insert("id".to_string(), Json::Num(upstream_id as f64));
        }
        if writeln!(up.writer, "{line}").is_err() {
            // Undo this request first so the caller's error reply is the
            // only answer its client sees, then abandon the connection
            // (draining everyone else's pendings with retryable replies).
            // The trace builder returns to the caller for the fail-over.
            if let Some(route) = self.pending.lock().unwrap().remove(&upstream_id) {
                *trace = route.trace.map(|(b, _)| b);
            }
            self.inflight.fetch_sub(1, Ordering::AcqRel);
            self.abandon(conn);
            return Err(ForwardError::Down);
        }
        self.forwarded.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Establish the pooled pipelined connection if it is gone: dial,
    /// `hello` handshake (the backend must advertise pipelining; its
    /// `max_inflight` caps our window), spawn the reader thread. True when
    /// a connection is up on return.
    pub fn ensure_connected(self: &Arc<Self>) -> bool {
        if self.conn.lock().unwrap().is_some() {
            return true;
        }
        let Ok(stream) = self.dial() else {
            return false;
        };
        let Some(advertised) = hello_handshake(&stream, self.io_timeout) else {
            return false;
        };
        *self.schemes.lock().unwrap() = advertised.schemes.clone();
        let read_half = match stream.try_clone() {
            Ok(s) => s,
            Err(_) => return false,
        };
        // Short read timeout so the reader notices stop/reconnect. Writes
        // are bounded by the *probe* timeout: forward() holds the conn
        // mutex across its write, so a wedged backend may stall routing
        // (and the health monitor's mark_down, which needs the same
        // mutex) for at most one probe window before the write fails,
        // the connection is abandoned, and keys fail over.
        if read_half.set_read_timeout(Some(Duration::from_millis(250))).is_err()
            || stream.set_write_timeout(Some(self.io_timeout)).is_err()
        {
            return false;
        }
        let mut conn = self.conn.lock().unwrap();
        if conn.is_some() {
            return true; // raced with another connector
        }
        let epoch = self.epoch.fetch_add(1, Ordering::AcqRel) + 1;
        *conn = Some(Upstream {
            writer: stream,
            window: self.cap.min(advertised.max_inflight.max(1)),
        });
        drop(conn);
        self.reconnects.fetch_add(1, Ordering::Relaxed);
        let me = self.clone();
        let mut readers = self.readers.lock().unwrap();
        readers.reap_finished();
        readers.spawn(format!("dither-backend-{}-reader", self.id), move || {
            reader_loop(&me, read_half, epoch);
        });
        true
    }

    /// Scrape the backend's `stats` over a short-lived connection (also
    /// the health probe: `None` means down/unresponsive within the
    /// timeout).
    pub fn fetch_stats(&self) -> Option<StatsSummary> {
        let stream = self.dial().ok()?;
        stream.set_read_timeout(Some(self.io_timeout)).ok()?;
        let mut reader = BufReader::new(stream.try_clone().ok()?);
        let mut writer = stream;
        writeln!(writer, "{{\"cmd\":\"stats\"}}").ok()?;
        writer.flush().ok()?;
        let mut line = String::new();
        reader.read_line(&mut line).ok()?;
        parse_stats(&line).ok()
    }

    /// Scrape the backend's trace ring over a short-lived connection —
    /// the fan-out side of the proxy's stitched `{"cmd":"trace"}` reply.
    /// `None` means down/unresponsive within the timeout (the stitched
    /// reply simply omits that backend's timelines).
    pub fn fetch_traces(&self, query: &TraceQuery) -> Option<Vec<Trace>> {
        let stream = self.dial().ok()?;
        stream.set_read_timeout(Some(self.io_timeout)).ok()?;
        let mut reader = BufReader::new(stream.try_clone().ok()?);
        let mut writer = stream;
        writeln!(writer, "{}", format_trace_query(query)).ok()?;
        writer.flush().ok()?;
        let mut line = String::new();
        reader.read_line(&mut line).ok()?;
        parse_traces(&line).ok()
    }

    /// Tear the backend down for proxy shutdown: abandon the connection
    /// (answering every pending reply) and join the reader threads.
    pub fn shutdown(&self) {
        self.mark_down();
        self.readers.lock().unwrap().join_all();
    }

    fn dial(&self) -> std::io::Result<TcpStream> {
        let mut addrs = self.addr.to_socket_addrs()?;
        let sock = addrs.next().ok_or_else(|| {
            std::io::Error::new(std::io::ErrorKind::InvalidInput, "unresolvable address")
        })?;
        let stream = TcpStream::connect_timeout(&sock, self.io_timeout)?;
        stream.set_nodelay(true).ok();
        Ok(stream)
    }

    /// Drop the pooled connection (if any), mark the backend down, and
    /// answer every pending reply with a retryable `overloaded` line.
    fn abandon(&self, mut conn: MutexGuard<'_, Option<Upstream>>) {
        let _ = conn.take();
        self.healthy.store(false, Ordering::Release);
        drop(conn);
        let drained: Vec<Route> = self.pending.lock().unwrap().drain().map(|(_, r)| r).collect();
        for route in drained {
            self.lost.fetch_add(1, Ordering::Relaxed);
            self.inflight.fetch_sub(1, Ordering::AcqRel);
            let _ = route.tx.send(format_overloaded(route.client_id));
            // The request died with the connection — commit its timeline
            // anyway (noted, so a trace query shows where it was lost).
            if let Some((mut builder, submitted)) = route.trace {
                builder.span_noted(
                    Stage::UpstreamWait,
                    submitted,
                    Instant::now(),
                    Some("abandoned".to_string()),
                );
                self.tracer.finish(builder);
            }
        }
    }

    /// Reader-thread teardown: only acts if `epoch` is still the live
    /// connection (a reconnect supersedes the old reader, which then just
    /// exits).
    fn teardown(&self, epoch: u64) {
        let conn = self.conn.lock().unwrap();
        if self.epoch.load(Ordering::Acquire) != epoch {
            return;
        }
        self.abandon(conn);
    }
}

/// `hello` handshake on a fresh upstream connection: the backend must
/// advertise `pipelined`; returns the parsed [`HelloInfo`] (window cap
/// plus the scheme list — defaulted to the paper's trio for a v1
/// backend that predates the `schemes` field).
fn hello_handshake(stream: &TcpStream, io_timeout: Duration) -> Option<HelloInfo> {
    stream.set_read_timeout(Some(io_timeout)).ok()?;
    let mut reader = BufReader::new(stream.try_clone().ok()?);
    let mut writer = stream;
    writeln!(writer, "{{\"cmd\":\"hello\"}}").ok()?;
    writer.flush().ok()?;
    let mut line = String::new();
    reader.read_line(&mut line).ok()?;
    let hello = Json::parse(line.trim()).ok()?;
    let pipelined = hello
        .get("features")
        .and_then(Json::as_arr)
        .is_some_and(|f| f.iter().any(|v| v.as_str() == Some("pipelined")));
    if !pipelined {
        return None;
    }
    parse_hello(&line).ok()
}

/// Rewrite a backend reply's echoed upstream id back to the client's
/// original id. Field order is canonical (sorted) on both sides, so the
/// payload bytes are exactly what the backend emitted.
fn rewrite_reply_id(line: &str, client_id: u64) -> String {
    match Json::parse(line) {
        Ok(mut json) => {
            if let Json::Obj(fields) = &mut json {
                fields.insert("id".to_string(), Json::Num(client_id as f64));
            }
            json.to_string()
        }
        Err(_) => crate::coordinator::protocol::format_error(
            client_id,
            "unparseable backend reply",
            true,
        ),
    }
}

/// The pooled connection's reader: drains upstream completions in
/// whatever order the backend finishes them and routes each back to its
/// originating client. Exits on socket loss, proxy stop, or epoch
/// supersession, then tears the connection down (see
/// [`Backend::teardown`]).
fn reader_loop(backend: &Arc<Backend>, stream: TcpStream, epoch: u64) {
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        let stale = backend.epoch.load(Ordering::Acquire) != epoch;
        if stale || backend.stop.load(Ordering::Acquire) {
            break;
        }
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => break, // backend closed
            Ok(_) => {}
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                continue;
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => break,
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        // Every line on the pooled pipelined connection answers a
        // forwarded request, so it echoes the upstream id we assigned.
        // Unknown or id-less lines are stale duplicates — dropped.
        let Ok(upstream_id) = response_id(trimmed) else {
            continue;
        };
        let route = backend.pending.lock().unwrap().remove(&upstream_id);
        if let Some(route) = route {
            backend.inflight.fetch_sub(1, Ordering::AcqRel);
            let _ = route.tx.send(rewrite_reply_id(trimmed, route.client_id));
            if let Some((mut builder, submitted)) = route.trace {
                builder.span(Stage::UpstreamWait, submitted, Instant::now());
                backend.tracer.finish(builder);
            }
        }
    }
    backend.teardown(epoch);
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn backend() -> Arc<Backend> {
        backend_tracing(crate::trace::TraceConfig::default())
    }

    fn backend_tracing(trace: crate::trace::TraceConfig) -> Arc<Backend> {
        Arc::new(Backend::new(
            0,
            "127.0.0.1:1".to_string(), // nothing listens here
            4,
            Duration::from_millis(100),
            Arc::new(AtomicBool::new(false)),
            Arc::new(Tracer::new(trace)),
        ))
    }

    #[test]
    fn down_backend_refuses_forwards() {
        let b = backend();
        let (tx, rx) = channel();
        let req = Json::obj(vec![("id", Json::Num(7.0))]);
        assert_eq!(b.forward(&req, 7, &tx, &mut None), Err(ForwardError::Down));
        assert!(rx.try_recv().is_err(), "refused forwards must not reply");
        assert_eq!(b.forwarded(), 0);
        // Connecting to a dead address fails and leaves the backend down.
        assert!(!b.ensure_connected());
        assert!(!b.is_healthy());
        assert!(b.fetch_stats().is_none());
    }

    #[test]
    fn abandon_answers_pending_with_retryable_overloaded() {
        let b = backend();
        let (tx, rx) = channel();
        b.pending.lock().unwrap().insert(
            41,
            Route {
                client_id: 9,
                tx: tx.clone(),
                trace: None,
            },
        );
        b.inflight.fetch_add(1, Ordering::AcqRel);
        b.mark_up();
        b.mark_down();
        let line = rx.recv().unwrap();
        assert!(line.contains("\"overloaded\":true") && line.contains("\"id\":9"), "{line}");
        assert_eq!(b.inflight(), 0, "abandon releases window slots");
        assert_eq!(b.lost(), 1);
        assert!(!b.is_healthy());
    }

    #[test]
    fn abandon_commits_inflight_traces_with_an_abandoned_note() {
        // A sampled request whose backend dies mid-flight must still land
        // in the proxy's trace ring, with UpstreamWait noted "abandoned".
        let b = backend_tracing(crate::trace::TraceConfig {
            rate: 1.0,
            slow_us: 0,
            buffer: 8,
        });
        let (tx, rx) = channel();
        let mut builder = b.tracer.begin(55).expect("rate 1.0 samples everything");
        builder.span_since(Stage::Route, Instant::now());
        b.pending.lock().unwrap().insert(
            7,
            Route {
                client_id: 55,
                tx: tx.clone(),
                trace: Some((builder, Instant::now())),
            },
        );
        b.inflight.fetch_add(1, Ordering::AcqRel);
        b.mark_down();
        let line = rx.recv().unwrap();
        assert!(line.contains("\"overloaded\":true"), "{line}");
        let traces = b.tracer.query(0, None, None, 0);
        assert_eq!(traces.len(), 1, "abandoned trace must be committed");
        let wait = traces[0]
            .spans
            .iter()
            .find(|s| s.stage == Stage::UpstreamWait)
            .expect("abandon stamps UpstreamWait");
        assert_eq!(wait.note.as_deref(), Some("abandoned"));
    }

    #[test]
    fn reply_id_rewrite_preserves_payload() {
        let reply = crate::coordinator::protocol::format_response(
            981,
            3,
            crate::rounding::SchemeId::Dither,
            4,
            &[0.125, -0.5],
            77,
            2,
            1,
            false,
            false,
        );
        let rewritten = rewrite_reply_id(&reply, 12);
        assert_eq!(rewritten, reply.replace("\"id\":981", "\"id\":12"));
        assert!(rewrite_reply_id("garbage", 5).contains("unparseable backend reply"));
    }
}
