//! The cluster front tier: a TCP proxy that speaks the same newline-JSON
//! line protocol as `serve`, routes each inference request by its
//! model/configuration key over the consistent-hash ring to one of N
//! backend `serve` processes, and merges backend `stats` into one
//! cluster-wide view.
//!
//! Request path: a client connection is a reader/writer pair exactly like
//! the backend server's ([`crate::coordinator::server`]). The reader
//! parses each line once; control commands are answered locally, and
//! inference lines are routed by key —
//! `model/scheme/k=K` for concrete requests, `model/auto` for
//! auto-precision ones, so every request of one configuration lands on
//! one backend and batches there, and a model's auto traffic converges on
//! a single backend's estimators. Upstream, the proxy speaks the
//! pipelined protocol through each backend's pooled connection
//! ([`crate::cluster::backend`]); completions come back out of order and
//! are tagged to the originating client id, so one slow backend never
//! convoys another's replies.
//!
//! Failure model: a backend that fails its periodic health probe
//! ([`crate::cluster::health`]) is marked down and its keys
//! deterministically fail over to the next live ring member; requests
//! that were in flight on a lost connection are answered with retryable
//! `overloaded` replies. When every backend is down the proxy answers
//! `overloaded` (and `ping` stops reporting `pong`, so
//! [`crate::coordinator::server::wait_ready`] keeps waiting).
//!
//! `{"cmd":"stats"}` scrapes every healthy backend and merges: counters
//! are summed, `per_shard_requests` concatenated in backend order,
//! the raw log2 latency histograms (`latency_buckets`, per-scheme
//! `recent` buckets) are summed bucket-wise and the cluster-wide
//! p50/p95/p99 are recomputed from the merged histogram — true cluster
//! percentiles, not per-backend maxima. A backend of an older build that
//! omits histograms still contributes its own percentiles as a
//! per-backend-max upper bound. The `fidelity`
//! blocks merge per `(model, scheme, k)` with the exact parallel-Welford
//! reduction the backends use shard-to-shard — the cluster-wide
//! estimator view. Proxy-tier counters ride in a `proxy` sub-object.
//! `{"cmd":"shutdown"}` stops the **proxy only**; backends keep serving.
//!
//! Observability: the proxy runs its own [`Tracer`] (`--trace-rate`,
//! `--trace-slow-us`, `--trace-buffer`). A sampled request gets a
//! proxy-side timeline — `route` (ring lookup), `forward` (request
//! rewrite), `upstream_wait` (submit → completion) — and its context
//! rides upstream in the request line's `"trace"` field, so the backend
//! records the same trace id (proto 3; older backends ignore the field).
//! `{"cmd":"trace"}` then stitches: the proxy's own matching timelines
//! are returned with each backend's same-id timelines attached as an
//! `"upstream"` array (tagged with the serving backend's address), and
//! backend timelines whose proxy-side context is gone are appended
//! standalone. `{"cmd":"metrics"}` (and a raw `GET /metrics` line)
//! serves the merged cluster view in Prometheus text exposition format,
//! plus proxy-tier counters, per-backend gauges, and the proxy tracer's
//! stage histograms.

use crate::cluster::backend::{Backend, ForwardError};
use crate::cluster::health::{health_loop, HealthPolicy};
use crate::cluster::ring::{HashRing, DEFAULT_REPLICAS};
use crate::coordinator::metrics::{approx_sum_us, bucket_upper, percentile_from_buckets, BUCKETS};
use crate::coordinator::protocol::{
    format_error, format_hello, format_metrics_reply, format_overloaded, format_unwatch_ack,
    format_watch, format_watch_ack, line_id, parse_message, parse_watch_ack, FidelityCell,
    Message, StatsSummary, TraceQuery, WatchQuery, PROTO_VERSION,
};
use crate::coordinator::server::http_metrics_response;
use crate::obs::{self, parse_event_line, Event, EventKind, Journal, Severity, Subscription};
use crate::trace::{decode_wire, PromText, Stage, Trace, TraceConfig, Tracer};
use crate::util::error::{Context, Result};
use crate::util::json::Json;
use crate::util::threadpool::WorkerPool;
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Front-tier configuration.
#[derive(Clone, Debug)]
pub struct ProxyConfig {
    /// Listen address, e.g. `127.0.0.1:7900`.
    pub addr: String,
    /// Backend `serve` addresses (ring member ids follow list order).
    pub backends: Vec<String>,
    /// Virtual nodes per backend on the hash ring.
    pub replicas: usize,
    /// Per-backend in-flight window cap (the backend's advertised
    /// `max_inflight` may lower it).
    pub backend_inflight: usize,
    /// Health-probe interval in milliseconds (and backoff floor).
    pub probe_interval_ms: u64,
    /// Probe / connect / upstream-handshake timeout in milliseconds.
    pub probe_timeout_ms: u64,
    /// Probe backoff ceiling for dead backends, in milliseconds.
    pub max_backoff_ms: u64,
    /// Fraction of requests that get a proxy-side trace timeline
    /// (`--trace-rate`; 0 disables sampling).
    pub trace_rate: f64,
    /// Promote any request at least this slow (µs) into the trace ring,
    /// sampled or not (`--trace-slow-us`; 0 disables promotion).
    pub trace_slow_us: u64,
    /// Completed-trace ring capacity (`--trace-buffer`).
    pub trace_buffer: usize,
}

impl Default for ProxyConfig {
    fn default() -> Self {
        ProxyConfig {
            addr: "127.0.0.1:7900".to_string(),
            backends: Vec::new(),
            replicas: DEFAULT_REPLICAS,
            backend_inflight: 64,
            probe_interval_ms: 500,
            probe_timeout_ms: 2_000,
            max_backoff_ms: 8_000,
            trace_rate: 0.0,
            trace_slow_us: 0,
            trace_buffer: 256,
        }
    }
}

/// Shared proxy state: the backend handles, the ring, and scrape counters.
struct Cluster {
    backends: Vec<Arc<Backend>>,
    ring: HashRing,
    stop: Arc<AtomicBool>,
    started: Instant,
    /// Requests the proxy itself bounced (no live backend / window full).
    overloaded: AtomicU64,
    /// Lines the proxy itself failed (bad JSON, unknown cmd).
    errors: AtomicU64,
    /// Client reply lines delivered, and the flushes they coalesced into.
    flushed_lines: AtomicU64,
    flushes: AtomicU64,
    /// The proxy tier's own tracer: route/forward/upstream-wait timelines
    /// land here (backends finish them on reply arrival).
    tracer: Arc<Tracer>,
    /// The proxy's own event journal: local lifecycle and health events
    /// plus every healthy backend's stream stitched in (each stitched
    /// event tagged with its `backend` id). Cluster-level watches and the
    /// merged alert gauges serve from here.
    journal: Arc<Journal>,
    /// Process start in Unix seconds, echoed as `start_time` in merged
    /// stats (mirrors the backend tier).
    start_unix: u64,
}

impl Cluster {
    fn any_healthy(&self) -> bool {
        self.backends.iter().any(|b| b.is_healthy())
    }

    fn healthy_count(&self) -> usize {
        self.backends.iter().filter(|b| b.is_healthy()).count()
    }
}

/// Run the front tier until a `shutdown` command arrives. Blocks.
pub fn run_proxy(cfg: &ProxyConfig) -> Result<()> {
    if cfg.backends.is_empty() {
        crate::bail!("proxy needs at least one backend address (the hash ring cannot be empty)");
    }
    let listener = TcpListener::bind(&cfg.addr)
        .with_context(|| format!("binding {}", cfg.addr))?;
    listener.set_nonblocking(true)?;
    let stop = Arc::new(AtomicBool::new(false));
    let io_timeout = Duration::from_millis(cfg.probe_timeout_ms.max(100));
    let tracer = Arc::new(Tracer::new(TraceConfig {
        rate: cfg.trace_rate,
        slow_us: cfg.trace_slow_us,
        buffer: cfg.trace_buffer,
    }));
    let backends: Vec<Arc<Backend>> = cfg
        .backends
        .iter()
        .enumerate()
        .map(|(id, addr)| {
            Arc::new(Backend::new(
                id,
                addr.clone(),
                cfg.backend_inflight.max(1),
                io_timeout,
                stop.clone(),
                tracer.clone(),
            ))
        })
        .collect();
    let cluster = Arc::new(Cluster {
        ring: HashRing::with_members(cfg.replicas.max(1), backends.len()),
        backends,
        stop: stop.clone(),
        started: Instant::now(),
        overloaded: AtomicU64::new(0),
        errors: AtomicU64::new(0),
        flushed_lines: AtomicU64::new(0),
        flushes: AtomicU64::new(0),
        tracer,
        journal: Arc::new(Journal::default()),
        start_unix: std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0),
    });
    cluster.journal.publish(
        Severity::Info,
        EventKind::ProcessStart,
        &[
            ("tier", "proxy"),
            ("kernel", crate::kernels::active_id().name()),
            ("backends", &cfg.backends.len().to_string()),
        ],
    );
    let policy = HealthPolicy {
        interval: Duration::from_millis(cfg.probe_interval_ms.max(10)),
        timeout: io_timeout,
        max_backoff: Duration::from_millis(cfg.max_backoff_ms.max(cfg.probe_interval_ms.max(10))),
    };
    let mut service = WorkerPool::new();
    {
        let cluster = cluster.clone();
        let stop = stop.clone();
        service.spawn("dither-proxy-health".to_string(), move || {
            health_loop(&cluster.backends, &policy, &stop, Some(&cluster.journal));
        });
    }
    // One stitcher per backend: a persistent watch subscription whose
    // events land in the proxy journal tagged with the backend id, so a
    // single cluster-level watch observes the whole fleet.
    for idx in 0..cluster.backends.len() {
        let cluster = cluster.clone();
        service.spawn(format!("dither-proxy-watch-{idx}"), move || {
            watch_stitch_loop(&cluster, idx);
        });
    }
    println!(
        "dither-proxy listening on {} ({} backends x {} vnodes, window {}/backend)",
        cfg.addr,
        cfg.backends.len(),
        cfg.replicas.max(1),
        cfg.backend_inflight.max(1)
    );

    let mut conns = WorkerPool::new();
    let mut conn_id = 0u64;
    while !stop.load(Ordering::Acquire) {
        match listener.accept() {
            Ok((stream, _addr)) => {
                conn_id += 1;
                let id = conn_id;
                let cluster = cluster.clone();
                conns.spawn(format!("dither-proxy-conn-{id}"), move || {
                    let _ = handle_client(stream, id, &cluster);
                });
                if conn_id % 64 == 0 {
                    conns.reap_finished();
                }
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                conns.reap_finished();
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => {
                stop.store(true, Ordering::Release);
                conns.join_all();
                for b in &cluster.backends {
                    b.shutdown();
                }
                service.join_all();
                return Err(e.into());
            }
        }
    }
    // Client readers see the stop flag and drop their channels; writers
    // drain the replies still in flight from backend readers before the
    // backends are torn down.
    conns.join_all();
    for b in &cluster.backends {
        b.shutdown();
    }
    service.join_all();
    println!("dither-proxy stopped");
    Ok(())
}

/// The routing key of one request line: every request of one concrete
/// configuration shares a key (and therefore a backend, where it
/// batches); a model's auto-precision traffic shares one key so a single
/// backend's estimators see all of it.
fn route_key(json: &Json) -> String {
    let model = json.get("model").and_then(Json::as_str).unwrap_or("digits_linear");
    let scheme = json
        .get("scheme")
        .or_else(|| json.get("mode"))
        .and_then(Json::as_str);
    let k = json.get("k").and_then(Json::as_usize).unwrap_or(0);
    if scheme == Some("auto") || k == 0 {
        format!("{model}/auto")
    } else {
        format!("{model}/{}/k={k}", scheme.unwrap_or("?"))
    }
}

/// One client connection: reader half here, writer thread alongside —
/// the same split as the backend server, so control acks and routed
/// completions funnel through one channel and the socket has one writer.
/// The channel is unbounded but de-facto bounded: at most the sum of the
/// backend windows plus one control line can be outstanding.
fn handle_client(stream: TcpStream, conn_id: u64, cluster: &Arc<Cluster>) -> Result<()> {
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(Duration::from_millis(250)))?;
    let write_half = stream.try_clone()?;
    write_half.set_write_timeout(Some(Duration::from_secs(30)))?;
    let (tx, rx) = channel::<String>();
    let writer_alive = Arc::new(AtomicBool::new(true));
    let alive = writer_alive.clone();
    let wcluster = cluster.clone();
    let writer = std::thread::Builder::new()
        .name(format!("dither-proxy-conn-{conn_id}-writer"))
        .spawn(move || client_writer(write_half, rx, &alive, &wcluster))?;
    let result = client_read_loop(stream, cluster, &tx, &writer_alive);
    drop(tx);
    let _ = writer.join();
    result
}

/// Writer half: the shared writer-drain protocol
/// ([`crate::coordinator::server::drain_replies`]), with flushes counted
/// cluster-wide for the `proxy` stats block.
fn client_writer(stream: TcpStream, rx: Receiver<String>, alive: &AtomicBool, cluster: &Cluster) {
    crate::coordinator::server::drain_replies(stream, rx, alive, |lines| {
        cluster.flushes.fetch_add(1, Ordering::Relaxed);
        cluster.flushed_lines.fetch_add(lines as u64, Ordering::Relaxed);
    });
}

/// Stream-stitcher for one backend: while the backend is healthy, hold a
/// dedicated watch subscription against it and re-publish everything it
/// emits into the proxy's journal. A dead backend (or a dropped stream)
/// is re-dialed once health probes mark it up again; the backend journal
/// streams live events only (no replay), so a re-subscribe can never
/// duplicate what an earlier session already stitched.
fn watch_stitch_loop(cluster: &Cluster, idx: usize) {
    let id_label = cluster.backends[idx].id().to_string();
    while !cluster.stop.load(Ordering::Acquire) {
        if !cluster.backends[idx].is_healthy() {
            std::thread::sleep(Duration::from_millis(100));
            continue;
        }
        if stitch_session(cluster, idx, &id_label).is_none() {
            // The stream died while the proxy is still running: brief
            // pause before the redial so a flapping backend is not
            // hammered (health probes gate the retry anyway).
            std::thread::sleep(Duration::from_millis(200));
        }
    }
}

/// One watch session against backend `idx`: dial, subscribe to every
/// event, and stitch the stream until it dies (`None`) or the proxy
/// stops (`Some(())`).
fn stitch_session(cluster: &Cluster, idx: usize, id_label: &str) -> Option<()> {
    use std::net::ToSocketAddrs;
    let backend = &cluster.backends[idx];
    let dial_timeout = Duration::from_secs(2);
    let sock = backend.addr().to_socket_addrs().ok()?.next()?;
    let stream = TcpStream::connect_timeout(&sock, dial_timeout).ok()?;
    stream.set_nodelay(true).ok();
    stream
        .set_read_timeout(Some(Duration::from_millis(250)))
        .ok()?;
    let mut writer = stream.try_clone().ok()?;
    let mut reader = BufReader::new(stream);
    writeln!(writer, "{}", format_watch(&WatchQuery::default())).ok()?;
    let mut line = String::new();
    let mut acked = false;
    let ack_deadline = Instant::now() + dial_timeout;
    loop {
        if cluster.stop.load(Ordering::Acquire) {
            return Some(());
        }
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => return None,
            Ok(_) => {}
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                if !acked && Instant::now() > ack_deadline {
                    return None; // backend never acked the subscription
                }
                continue;
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => return None,
        }
        if !acked {
            if parse_watch_ack(line.trim()).is_err() {
                return None; // proto-3 backend or refused subscription
            }
            acked = true;
            continue;
        }
        if let Some((_sub, event)) = parse_event_line(&line) {
            stitch_event(cluster, id_label, event);
        }
    }
}

/// Fold one backend event into the proxy journal, tagged with its
/// backend id. Backend alert transitions go through the proxy's own
/// alert set instead of being copied verbatim: `set_alert` keeps the
/// cluster-wide active set deduplicated per (alert, labels, backend) and
/// publishes the proxy's own fired/cleared transition events, so a
/// re-subscribed or flapping stream cannot double-fire a gauge.
fn stitch_event(cluster: &Cluster, id_label: &str, mut event: Event) {
    event
        .labels
        .insert("backend".to_string(), id_label.to_string());
    match event.kind {
        EventKind::AlertFired | EventKind::AlertCleared => {
            let name = event
                .labels
                .get("alert")
                .cloned()
                .unwrap_or_else(|| "unknown".to_string());
            let labels: Vec<(&str, &str)> = event
                .labels
                .iter()
                .filter(|(k, _)| k.as_str() != "alert")
                .map(|(k, v)| (k.as_str(), v.as_str()))
                .collect();
            cluster
                .journal
                .set_alert(&name, &labels, event.kind == EventKind::AlertFired);
        }
        _ => {
            cluster
                .journal
                .publish_owned(event.severity, event.kind, event.labels);
        }
    }
}

/// Reader half: parse each line once, answer control locally, route
/// inference upstream.
fn client_read_loop(
    stream: TcpStream,
    cluster: &Arc<Cluster>,
    tx: &Sender<String>,
    writer_alive: &AtomicBool,
) -> Result<()> {
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    // This connection's live cluster-level watch subscriptions; the
    // channel to the writer is unbounded, so the pump below never blocks
    // the reader.
    let mut watches: Vec<Arc<Subscription>> = Vec::new();
    let mut result: Result<()> = Ok(());
    loop {
        if !writer_alive.load(Ordering::Acquire) {
            break;
        }
        // Deliver pending stitched events; read-timeout ticks keep this
        // pumping even on an idle connection.
        for sub in &watches {
            while let Some(event_line) = sub.pop() {
                if tx.send(event_line).is_err() {
                    break;
                }
            }
        }
        match reader.read_line(&mut line) {
            Ok(0) => break,
            Ok(_) => {}
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                if cluster.stop.load(Ordering::Acquire) {
                    break;
                }
                continue;
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => {
                result = Err(e.into());
                break;
            }
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            line.clear();
            continue;
        }
        // Raw Prometheus scrape: answer with an HTTP response and close
        // (same fast path as the backend server).
        if trimmed.starts_with("GET ") {
            let _ = tx.send(http_metrics_response(&proxy_metrics_text(cluster)));
            break;
        }
        let mut stop = false;
        let sent = match Json::parse(trimmed) {
            Ok(json) => match json.get("cmd").and_then(Json::as_str) {
                // `pong` only with a live backend: wait_ready against the
                // proxy then means "the cluster can actually serve".
                Some("ping") => {
                    if cluster.any_healthy() {
                        tx.send("{\"pong\":true}".to_string())
                    } else {
                        tx.send("{\"error\":\"no healthy backends\"}".to_string())
                    }
                }
                // Advertise the sum of the backend windows — the true
                // bound on what one client can usefully keep in flight
                // through this proxy — and the schemes every healthy
                // backend agrees it can serve.
                Some("hello") => {
                    let schemes = advertised_schemes(cluster);
                    let names: Vec<&str> = schemes.iter().map(String::as_str).collect();
                    tx.send(format_hello(
                        cluster.backends.iter().map(|b| b.cap()).sum::<usize>().max(1),
                        &names,
                        crate::kernels::active_id().name(),
                    ))
                }
                Some("stats") => tx.send(merged_stats_json(cluster)),
                Some("trace") => tx.send(stitched_traces_json(cluster, &json)),
                Some("metrics") => tx.send(format_metrics_reply(&proxy_metrics_text(cluster))),
                // Cluster-level watches subscribe to the proxy journal:
                // local lifecycle + health events plus every backend's
                // stitched stream, one subscription for the whole fleet.
                Some("watch") => match parse_message(trimmed) {
                    Ok(Message::Watch(q)) => {
                        let sub = cluster.journal.subscribe(
                            q.severity.unwrap_or(Severity::Info),
                            q.kinds,
                            0,
                        );
                        let ack = format_watch_ack(sub.id());
                        watches.push(sub);
                        tx.send(ack)
                    }
                    Err(e) => {
                        cluster.errors.fetch_add(1, Ordering::Relaxed);
                        tx.send(format_error(0, &e, false))
                    }
                    Ok(_) => {
                        cluster.errors.fetch_add(1, Ordering::Relaxed);
                        tx.send(format_error(0, "bad watch line", false))
                    }
                },
                Some("unwatch") => match parse_message(trimmed) {
                    Ok(Message::Unwatch(id)) => {
                        // Only this connection's own subscriptions can be
                        // torn down.
                        let removed = watches.iter().any(|s| s.id() == id)
                            && cluster.journal.unsubscribe(id);
                        watches.retain(|s| s.id() != id);
                        tx.send(format_unwatch_ack(id, removed))
                    }
                    Err(e) => {
                        cluster.errors.fetch_add(1, Ordering::Relaxed);
                        tx.send(format_error(0, &e, false))
                    }
                    Ok(_) => {
                        cluster.errors.fetch_add(1, Ordering::Relaxed);
                        tx.send(format_error(0, "bad unwatch line", false))
                    }
                },
                Some("shutdown") => {
                    cluster.stop.store(true, Ordering::Release);
                    stop = true;
                    tx.send("{\"stopping\":true}".to_string())
                }
                Some(other) => {
                    cluster.errors.fetch_add(1, Ordering::Relaxed);
                    tx.send(format_error(0, &format!("unknown cmd {other:?}"), false))
                }
                None => dispatch(cluster, &json, tx),
            },
            Err(e) => {
                cluster.errors.fetch_add(1, Ordering::Relaxed);
                tx.send(format_error(line_id(trimmed), &e.to_string(), false))
            }
        };
        if sent.is_err() {
            break;
        }
        line.clear();
        if stop {
            break;
        }
    }
    // Tear down this connection's subscriptions on every exit path so
    // the journal stops queueing events for a dead watcher.
    for sub in &watches {
        cluster.journal.unsubscribe(sub.id());
    }
    result
}

/// Schemes servable cluster-wide: the intersection of what every healthy
/// backend advertised in its `hello` handshake. When no healthy backend
/// has reported a list yet, fall back to the proxy's own registry —
/// nothing is servable until a backend comes up anyway, and the registry
/// is what a freshly probed-up backend of the same build will advertise.
fn advertised_schemes(cluster: &Cluster) -> Vec<String> {
    let mut acc: Option<Vec<String>> = None;
    for b in &cluster.backends {
        if !b.is_healthy() {
            continue;
        }
        let schemes = b.schemes();
        if schemes.is_empty() {
            continue;
        }
        acc = Some(match acc {
            None => schemes,
            Some(have) => have.into_iter().filter(|s| schemes.contains(s)).collect(),
        });
    }
    acc.unwrap_or_else(|| {
        crate::rounding::SchemeRegistry::global()
            .wire_names()
            .iter()
            .map(|s| (*s).to_string())
            .collect()
    })
}

/// Route one inference request: pick the key's owner among live backends,
/// forward, and fail over once if the pooled connection died between the
/// health check and the submit. Window-full backpressure and all-down
/// both answer `overloaded` — retryable by design.
///
/// Sampled requests (proxy tracer, or an upstream `"trace"` tag the
/// client supplied) get a proxy-side timeline: `route` around the ring
/// lookup, `forward` around the request rewrite, and `upstream_wait`
/// stamped by the backend reader on completion. The context propagates
/// upstream in the forwarded line's `"trace"` field so the serving
/// backend records the same trace id. A request the proxy bounces
/// (`overloaded`) commits its partial timeline immediately.
fn dispatch(
    cluster: &Arc<Cluster>,
    json: &Json,
    tx: &Sender<String>,
) -> std::result::Result<(), std::sync::mpsc::SendError<String>> {
    // Only objects can carry the rewritten upstream id (and the backend
    // echoes an id only for object lines); anything else would leave its
    // pending entry unanswerable, so refuse it here.
    if !matches!(json, Json::Obj(_)) {
        cluster.errors.fetch_add(1, Ordering::Relaxed);
        return tx.send(format_error(0, "request must be a json object", false));
    }
    let client_id = json
        .get("id")
        .and_then(Json::as_f64)
        .map(|v| v as u64)
        .unwrap_or(0);
    let mut trace = match json.get("trace").and_then(Json::as_str).and_then(decode_wire) {
        Some((id, flags)) => cluster.tracer.adopt(client_id, id, flags),
        None => cluster.tracer.begin(client_id),
    };
    let route_start = trace.as_ref().map(|_| Instant::now());
    let key = route_key(json);
    let healthy = |m: usize| cluster.backends[m].is_healthy();
    let owner = cluster.ring.route_where(&key, healthy);
    if let Some(b) = trace.as_deref_mut() {
        b.span_since(Stage::Route, route_start.unwrap());
        let model = json.get("model").and_then(Json::as_str).unwrap_or("digits_linear");
        let scheme = json
            .get("scheme")
            .or_else(|| json.get("mode"))
            .and_then(Json::as_str)
            .unwrap_or("auto");
        let k = json.get("k").and_then(Json::as_usize).unwrap_or(0) as u32;
        b.annotate(model, scheme, k);
    }
    let Some(owner) = owner else {
        cluster.overloaded.fetch_add(1, Ordering::Relaxed);
        if let Some(b) = trace.take() {
            cluster.tracer.finish(b);
        }
        return tx.send(format_overloaded(client_id));
    };
    // Propagate the trace context upstream: the forwarded line carries
    // our wire tag (proto 3 — a pre-trace backend just ignores it).
    let tagged = trace.as_ref().map(|b| {
        let forward_start = Instant::now();
        let mut line = json.clone();
        if let Json::Obj(fields) = &mut line {
            fields.insert("trace".to_string(), Json::Str(b.wire_tag()));
        }
        (line, forward_start)
    });
    let req = tagged.as_ref().map_or(json, |(line, _)| line);
    if let (Some(b), Some((_, start))) = (trace.as_deref_mut(), tagged.as_ref()) {
        b.span_since(Stage::Forward, *start);
    }
    let sent = match cluster.backends[owner].forward(req, client_id, tx, &mut trace) {
        Ok(()) => Ok(()),
        Err(ForwardError::Busy) => {
            // Backpressure stays on the key's owner: spilling a hot key
            // to another backend would shatter its batches.
            cluster.overloaded.fetch_add(1, Ordering::Relaxed);
            tx.send(format_overloaded(client_id))
        }
        Err(ForwardError::Down) => {
            // The pooled connection died after the health check; fail
            // over once to the key's deterministic successor. The trace
            // builder survived the refusal and follows the retry.
            let next = cluster.ring.route_where(&key, |m| m != owner && healthy(m));
            let forwarded =
                next.map(|m| cluster.backends[m].forward(req, client_id, tx, &mut trace));
            match forwarded {
                Some(Ok(())) => Ok(()),
                _ => {
                    cluster.overloaded.fetch_add(1, Ordering::Relaxed);
                    tx.send(format_overloaded(client_id))
                }
            }
        }
    };
    // A bounced request never reaches a backend reader: commit whatever
    // timeline it accumulated so trace queries still see it.
    if let Some(b) = trace.take() {
        cluster.tracer.finish(b);
    }
    sent
}

/// The merged cluster-wide view of a set of backend `stats` summaries —
/// the shared substrate of the JSON `stats` merge and the Prometheus
/// `metrics` exposition. Pure over the summaries (no sockets), so the
/// merge semantics — bucket-wise histogram sums, the legacy bucket-less
/// percentile fallback, per-cell window and fidelity reductions — are
/// directly testable.
struct MergedStats {
    /// Counters summed; percentiles resolved (merged-histogram values,
    /// kept an upper bound by any legacy backend's own percentiles).
    total: StatsSummary,
    /// Per-shard request counts concatenated in backend order.
    per_shard: Vec<f64>,
    /// Bucket-wise sum of the lifetime latency histograms.
    bucket_sum: Vec<u64>,
    /// Merged recent-window cells keyed as `stats.recent` keys them
    /// (scheme wire names and `model/k=K`).
    recent: BTreeMap<String, (u64, Vec<u64>)>,
    /// Fidelity cells merged per `(model, scheme, k)` via parallel
    /// Welford.
    cells: BTreeMap<(String, String, u32), FidelityCell>,
    /// Backend kernel consensus: agreed label, `"mixed"`, or `None` when
    /// no backend reported one.
    kernel: Option<String>,
    /// Summaries that went into the merge.
    reporting: usize,
}

/// Merge backend `stats` summaries (see the module docs for semantics).
fn merge_summaries(summaries: &[StatsSummary]) -> MergedStats {
    let mut total = StatsSummary::default();
    let mut per_shard: Vec<f64> = Vec::new();
    let mut cells: BTreeMap<(String, String, u32), FidelityCell> = BTreeMap::new();
    // Bucket-wise histogram sum across backends; legacy backends that
    // omit histograms contribute their own percentiles as an upper bound.
    let mut bucket_sum = vec![0u64; BUCKETS];
    let mut any_buckets = false;
    let mut legacy = (0.0f64, 0.0f64, 0.0f64); // (p50, p95, p99) maxima
    let mut recent: BTreeMap<String, (u64, Vec<u64>)> = BTreeMap::new();
    let mut kernel: Option<String> = None;
    for s in summaries {
        total.requests += s.requests;
        total.errors += s.errors;
        total.rejected += s.rejected;
        total.timeouts += s.timeouts;
        total.deprecated_fields += s.deprecated_fields;
        total.batches += s.batches;
        total.batched_requests += s.batched_requests;
        total.latency_sum_us += s.latency_sum_us;
        if s.latency_buckets.is_empty() {
            legacy.0 = legacy.0.max(s.p50_us);
            legacy.1 = legacy.1.max(s.p95_us);
            legacy.2 = legacy.2.max(s.p99_us);
        } else {
            any_buckets = true;
            if s.latency_buckets.len() > bucket_sum.len() {
                bucket_sum.resize(s.latency_buckets.len(), 0);
            }
            for (i, &b) in s.latency_buckets.iter().enumerate() {
                bucket_sum[i] += b;
            }
        }
        for cell in &s.recent {
            let slot = recent
                .entry(cell.scheme.clone())
                .or_insert_with(|| (0, vec![0u64; BUCKETS]));
            slot.0 += cell.requests;
            if cell.buckets.len() > slot.1.len() {
                slot.1.resize(cell.buckets.len(), 0);
            }
            for (i, &b) in cell.buckets.iter().enumerate() {
                slot.1[i] += b;
            }
        }
        total.uptime_s = total.uptime_s.max(s.uptime_s);
        total.shards += s.shards;
        total.writer_flushes += s.writer_flushes;
        total.writer_flushed_lines += s.writer_flushed_lines;
        total.recent_dropped += s.recent_dropped;
        total.auto_slo_requests += s.auto_slo_requests;
        total.auto_measured += s.auto_measured;
        per_shard.extend_from_slice(&s.per_shard_requests);
        for cell in &s.fidelity {
            let slot = (cell.model.clone(), cell.scheme.wire_name().to_string(), cell.k);
            cells
                .entry(slot)
                .and_modify(|have| have.estimate.merge(&cell.estimate))
                .or_insert_with(|| cell.clone());
        }
        // Kernel consensus: agreed label, "mixed" when backends differ.
        if let Some(k) = &s.kernel {
            kernel = Some(match kernel {
                None => k.clone(),
                Some(have) if have == *k => have,
                Some(_) => "mixed".to_string(),
            });
        }
    }
    // True cluster percentiles from the merged histogram; any legacy
    // (bucket-less) backend's own percentiles keep the result an upper
    // bound for its share of the traffic.
    total.p50_us = legacy.0;
    total.p95_us = legacy.1;
    total.p99_us = legacy.2;
    if any_buckets {
        total.p50_us = total.p50_us.max(percentile_from_buckets(&bucket_sum, 0.50));
        total.p95_us = total.p95_us.max(percentile_from_buckets(&bucket_sum, 0.95));
        total.p99_us = total.p99_us.max(percentile_from_buckets(&bucket_sum, 0.99));
    }
    MergedStats {
        total,
        per_shard,
        bucket_sum,
        recent,
        cells,
        kernel,
        reporting: summaries.len(),
    }
}

/// Scrape every healthy backend's `stats` concurrently. Fresh rather
/// than reusing the health prober's last fetch — operators (and the CI
/// sum checks) expect point-in-time counters, not probe-interval-stale
/// ones — and concurrent, so one slow backend costs one probe timeout,
/// not one per backend.
fn scrape_stats(cluster: &Cluster) -> Vec<StatsSummary> {
    let healthy: Vec<&Arc<Backend>> = cluster.backends.iter().filter(|b| b.is_healthy()).collect();
    std::thread::scope(|scope| {
        let fetches: Vec<_> = healthy
            .iter()
            .map(|b| scope.spawn(move || b.fetch_stats()))
            .collect();
        fetches
            .into_iter()
            .filter_map(|f| f.join().ok().flatten())
            .collect()
    })
}

/// Scrape every healthy backend and merge into one `stats` JSON line (see
/// the module docs for the merge semantics).
fn merged_stats_json(cluster: &Cluster) -> String {
    let summaries = scrape_stats(cluster);
    let m = merge_summaries(&summaries);
    let MergedStats {
        total,
        per_shard,
        bucket_sum,
        recent,
        cells,
        ..
    } = &m;
    let mean_batch = if total.batches == 0 {
        0.0
    } else {
        total.batched_requests as f64 / total.batches as f64
    };
    let mean_us = if total.requests == 0 {
        0.0
    } else {
        total.latency_sum_us / total.requests as f64
    };
    let uptime = cluster.started.elapsed().as_secs_f64();
    let throughput = if uptime > 0.0 {
        total.requests as f64 / uptime
    } else {
        0.0
    };
    let fidelity: Vec<Json> = cells
        .values()
        .map(|cell| {
            Json::obj(vec![
                ("model", Json::Str(cell.model.clone())),
                ("scheme", Json::Str(cell.scheme.to_string())),
                ("k", Json::Num(f64::from(cell.k))),
                ("samples", Json::Num(cell.estimate.samples as f64)),
                ("bias", Json::Num(cell.estimate.bias)),
                ("mse", Json::Num(cell.estimate.mse())),
                ("variance", Json::Num(cell.estimate.variance())),
            ])
        })
        .collect();
    // The cluster-wide kernel label: the backends' when they agree,
    // "mixed" when they differ, the proxy's own build when none reported.
    let kernel = m
        .kernel
        .clone()
        .unwrap_or_else(|| crate::kernels::active_id().name().to_string());
    let recent_json: BTreeMap<String, Json> = recent
        .iter()
        .map(|(scheme, (requests, buckets))| {
            (
                scheme.clone(),
                Json::obj(vec![
                    ("requests", Json::Num(*requests as f64)),
                    ("p50_us", Json::Num(percentile_from_buckets(buckets, 0.50))),
                    ("p99_us", Json::Num(percentile_from_buckets(buckets, 0.99))),
                    (
                        "buckets",
                        Json::Arr(buckets.iter().map(|&b| Json::Num(b as f64)).collect()),
                    ),
                ]),
            )
        })
        .collect();
    let forwarded: Vec<f64> = cluster.backends.iter().map(|b| b.forwarded() as f64).collect();
    let inflight: Vec<f64> = cluster.backends.iter().map(|b| b.inflight() as f64).collect();
    let reconnects: Vec<f64> = cluster.backends.iter().map(|b| b.reconnects() as f64).collect();
    let lost: Vec<f64> = cluster.backends.iter().map(|b| b.lost() as f64).collect();
    let proxy = Json::obj(vec![
        ("backends", Json::Num(cluster.backends.len() as f64)),
        ("healthy", Json::Num(cluster.healthy_count() as f64)),
        ("reporting", Json::Num(m.reporting as f64)),
        ("overloaded", Json::Num(cluster.overloaded.load(Ordering::Relaxed) as f64)),
        ("errors", Json::Num(cluster.errors.load(Ordering::Relaxed) as f64)),
        ("uptime_s", Json::Num(uptime)),
        ("forwarded", Json::nums(&forwarded)),
        ("inflight", Json::nums(&inflight)),
        ("reconnects", Json::nums(&reconnects)),
        ("lost", Json::nums(&lost)),
        (
            "writer_flushes",
            Json::Num(cluster.flushes.load(Ordering::Relaxed) as f64),
        ),
        (
            "writer_flushed_lines",
            Json::Num(cluster.flushed_lines.load(Ordering::Relaxed) as f64),
        ),
        (
            "events_published",
            Json::Num(cluster.journal.published() as f64),
        ),
        (
            "alerts_active",
            Json::Num(cluster.journal.active_alerts().len() as f64),
        ),
    ]);
    Json::obj(vec![
        ("kernel", Json::Str(kernel)),
        ("requests", Json::Num(total.requests as f64)),
        ("errors", Json::Num(total.errors as f64)),
        ("rejected", Json::Num(total.rejected as f64)),
        ("timeouts", Json::Num(total.timeouts as f64)),
        ("deprecated_fields", Json::Num(total.deprecated_fields as f64)),
        ("batches", Json::Num(total.batches as f64)),
        ("mean_batch", Json::Num(mean_batch)),
        ("mean_us", Json::Num(mean_us)),
        ("p50_us", Json::Num(total.p50_us)),
        ("p95_us", Json::Num(total.p95_us)),
        ("p99_us", Json::Num(total.p99_us)),
        (
            "latency_buckets",
            Json::Arr(bucket_sum.iter().map(|&b| Json::Num(b as f64)).collect()),
        ),
        ("recent", Json::Obj(recent_json)),
        ("writer_flushes", Json::Num(total.writer_flushes as f64)),
        ("writer_flushed_lines", Json::Num(total.writer_flushed_lines as f64)),
        ("recent_dropped", Json::Num(total.recent_dropped as f64)),
        ("auto_slo_requests", Json::Num(total.auto_slo_requests as f64)),
        ("auto_measured", Json::Num(total.auto_measured as f64)),
        ("fidelity", Json::Arr(fidelity)),
        ("uptime_s", Json::Num(total.uptime_s)),
        ("start_time", Json::Num(cluster.start_unix as f64)),
        ("throughput_rps", Json::Num(throughput)),
        ("shards", Json::Num(total.shards as f64)),
        ("per_shard_requests", Json::nums(per_shard)),
        ("proxy", proxy),
    ])
    .to_string()
}

/// The proxy's Prometheus text exposition (the `{"cmd":"metrics"}` verb
/// and the raw `GET /metrics` fast path): the merged cluster-wide
/// counters, latency and recent-window histograms, and fidelity gauges —
/// structurally the same families the backend tier exposes — plus
/// proxy-tier counters, per-backend gauges, and the proxy tracer's own
/// counters and stage histograms.
fn proxy_metrics_text(cluster: &Cluster) -> String {
    let summaries = scrape_stats(cluster);
    let m = merge_summaries(&summaries);
    let mut p = PromText::new();
    p.scalar(
        "dither_requests_total",
        "counter",
        "Completed requests (cluster-wide)",
        m.total.requests as f64,
    );
    p.scalar(
        "dither_errors_total",
        "counter",
        "Protocol and execution errors (cluster-wide)",
        m.total.errors as f64,
    );
    p.scalar(
        "dither_rejected_total",
        "counter",
        "Overload rejections (cluster-wide)",
        m.total.rejected as f64,
    );
    p.scalar(
        "dither_timeouts_total",
        "counter",
        "Watchdog-answered requests (cluster-wide)",
        m.total.timeouts as f64,
    );
    p.scalar(
        "dither_batches_total",
        "counter",
        "Executed batches (cluster-wide)",
        m.total.batches as f64,
    );
    p.scalar(
        "dither_batched_requests_total",
        "counter",
        "Requests served inside batches (cluster-wide)",
        m.total.batched_requests as f64,
    );
    p.scalar(
        "dither_recent_dropped_total",
        "counter",
        "Samples dropped from per-(model, k) recent windows (cluster-wide)",
        m.total.recent_dropped as f64,
    );
    p.scalar(
        "dither_auto_slo_requests_total",
        "counter",
        "Auto requests resolved under a latency budget (cluster-wide)",
        m.total.auto_slo_requests as f64,
    );
    p.scalar(
        "dither_auto_measured_total",
        "counter",
        "Auto requests resolved from live measurements (cluster-wide)",
        m.total.auto_measured as f64,
    );
    p.scalar(
        "dither_uptime_seconds",
        "gauge",
        "Proxy uptime",
        cluster.started.elapsed().as_secs_f64(),
    );
    p.family(
        "dither_kernel_info",
        "gauge",
        "Cluster kernel consensus (value is always 1)",
    );
    let kernel = m
        .kernel
        .clone()
        .unwrap_or_else(|| crate::kernels::active_id().name().to_string());
    p.sample("dither_kernel_info", &[("kernel", &kernel)], 1.0);
    p.family(
        "dither_latency_us",
        "histogram",
        "Cluster-wide end-to-end request latency",
    );
    p.histogram_series(
        "dither_latency_us",
        &[],
        &m.bucket_sum,
        m.total.latency_sum_us,
        bucket_upper,
    );
    // Same labeled split as the backend tier: scheme cells as
    // {scheme="..."}, (model, k) cells as {model="...",k="..."}.
    if m.recent.values().any(|(count, _)| *count > 0) {
        p.family(
            "dither_recent_latency_us",
            "histogram",
            "Rotating-window request latency per scheme and per (model, k), cluster-wide",
        );
        for (key, (count, buckets)) in &m.recent {
            if *count == 0 {
                continue;
            }
            match key.split_once("/k=") {
                Some((model, k)) => p.histogram_series(
                    "dither_recent_latency_us",
                    &[("model", model), ("k", k)],
                    buckets,
                    approx_sum_us(buckets),
                    bucket_upper,
                ),
                None => p.histogram_series(
                    "dither_recent_latency_us",
                    &[("scheme", key)],
                    buckets,
                    approx_sum_us(buckets),
                    bucket_upper,
                ),
            }
        }
    }
    if !m.cells.is_empty() {
        p.family(
            "dither_fidelity_samples",
            "gauge",
            "Shadow samples per (model, scheme, k), cluster-wide",
        );
        for cell in m.cells.values() {
            let k = cell.k.to_string();
            p.sample(
                "dither_fidelity_samples",
                &[("model", &cell.model), ("scheme", cell.scheme.wire_name()), ("k", &k)],
                cell.estimate.samples as f64,
            );
        }
        p.family(
            "dither_fidelity_bias",
            "gauge",
            "Mean signed logit error per (model, scheme, k), cluster-wide",
        );
        for cell in m.cells.values() {
            let k = cell.k.to_string();
            p.sample(
                "dither_fidelity_bias",
                &[("model", &cell.model), ("scheme", cell.scheme.wire_name()), ("k", &k)],
                cell.estimate.bias,
            );
        }
        p.family(
            "dither_fidelity_mse",
            "gauge",
            "Mean squared logit error per (model, scheme, k), cluster-wide",
        );
        for cell in m.cells.values() {
            let k = cell.k.to_string();
            p.sample(
                "dither_fidelity_mse",
                &[("model", &cell.model), ("scheme", cell.scheme.wire_name()), ("k", &k)],
                cell.estimate.mse(),
            );
        }
    }
    // Proxy tier: cluster shape, bounce counters, per-backend gauges.
    p.scalar(
        "dither_proxy_backends",
        "gauge",
        "Configured backends",
        cluster.backends.len() as f64,
    );
    p.scalar(
        "dither_proxy_healthy_backends",
        "gauge",
        "Backends passing health probes",
        cluster.healthy_count() as f64,
    );
    p.scalar(
        "dither_proxy_reporting_backends",
        "gauge",
        "Backends that answered the merge scrape",
        m.reporting as f64,
    );
    p.scalar(
        "dither_proxy_overloaded_total",
        "counter",
        "Requests the proxy bounced (no live backend or window full)",
        cluster.overloaded.load(Ordering::Relaxed) as f64,
    );
    p.scalar(
        "dither_proxy_errors_total",
        "counter",
        "Lines the proxy itself failed (bad JSON, unknown cmd)",
        cluster.errors.load(Ordering::Relaxed) as f64,
    );
    let per_backend: [(&str, &str, &str, fn(&Backend) -> f64); 5] = [
        ("dither_proxy_forwarded_total", "counter", "Requests forwarded per backend", |b| {
            b.forwarded() as f64
        }),
        ("dither_proxy_lost_total", "counter", "Pending replies abandoned per backend", |b| {
            b.lost() as f64
        }),
        (
            "dither_proxy_reconnects_total",
            "counter",
            "Pooled-connection (re)establishments per backend",
            |b| b.reconnects() as f64,
        ),
        ("dither_proxy_inflight", "gauge", "Forwarded-but-unanswered requests per backend", |b| {
            b.inflight() as f64
        }),
        ("dither_proxy_backend_up", "gauge", "Per-backend health verdict (1 = up)", |b| {
            if b.is_healthy() {
                1.0
            } else {
                0.0
            }
        }),
    ];
    for (name, kind, help, value) in per_backend {
        p.family(name, kind, help);
        for b in &cluster.backends {
            p.sample(name, &[("backend", b.addr())], value(b));
        }
    }
    p.scalar(
        "dither_traces_begun_total",
        "counter",
        "Proxy trace contexts handed out (sampled + speculative)",
        cluster.tracer.begun() as f64,
    );
    p.scalar(
        "dither_traces_committed_total",
        "counter",
        "Proxy traces committed to the ring buffer",
        cluster.tracer.committed() as f64,
    );
    p.scalar(
        "dither_traces_slow_total",
        "counter",
        "Proxy traces promoted by the slow threshold",
        cluster.tracer.slow_promoted() as f64,
    );
    p.scalar(
        "dither_traces_evicted_total",
        "counter",
        "Proxy traces evicted from the full ring buffer",
        cluster.tracer.evicted() as f64,
    );
    p.scalar(
        "dither_traces_resident",
        "gauge",
        "Completed proxy traces resident in the ring buffer",
        cluster.tracer.resident() as f64,
    );
    p.stage_histograms(&cluster.tracer.stage_snapshots());
    // The proxy journal's event/alert families (cluster-wide: stitched
    // backend streams included) and the proxy's own build identity.
    cluster.journal.append_prometheus(&mut p);
    obs::append_build_info(
        &mut p,
        &format!("{}", PROTO_VERSION as u32),
        crate::kernels::active_id().name(),
        &crate::rounding::SchemeRegistry::global().wire_names().join(","),
    );
    p.finish()
}

/// The trace-query filters of a raw `{"cmd":"trace"}` line (the proxy
/// parses request lines itself rather than through `parse_message`).
fn trace_query_of(json: &Json) -> TraceQuery {
    TraceQuery {
        min_us: json
            .get("min_us")
            .and_then(Json::as_f64)
            .map(|v| v.max(0.0) as u64)
            .unwrap_or(0),
        model: json.get("model").and_then(Json::as_str).map(str::to_string),
        scheme: json.get("scheme").and_then(Json::as_str).map(str::to_string),
        limit: json.get("limit").and_then(Json::as_usize).unwrap_or(0),
    }
}

/// Stitch proxy-side timelines with backend dumps: each proxy trace
/// gains an `"upstream"` array of the same-id backend timelines (each
/// tagged with the serving backend's address), and backend timelines
/// whose proxy-side context is gone — evicted from the proxy ring, or
/// promoted only upstream — are appended standalone so nothing the
/// cluster retained is hidden. `limit` caps the stitched list (0 = no
/// cap). Pure (no sockets): the stitching semantics are directly
/// testable.
fn stitch(local: &[Trace], upstream: &[(String, Vec<Trace>)], limit: usize) -> Vec<Json> {
    let mut by_id: BTreeMap<u64, Vec<Json>> = BTreeMap::new();
    for (addr, traces) in upstream {
        for t in traces {
            let mut j = t.to_json();
            if let Json::Obj(fields) = &mut j {
                fields.insert("backend".to_string(), Json::Str(addr.clone()));
            }
            by_id.entry(t.trace_id).or_default().push(j);
        }
    }
    let mut out: Vec<Json> = Vec::new();
    for t in local {
        let mut j = t.to_json();
        if let Json::Obj(fields) = &mut j {
            if let Some(ups) = by_id.remove(&t.trace_id) {
                fields.insert("upstream".to_string(), Json::Arr(ups));
            }
        }
        out.push(j);
    }
    for (_, ups) in by_id {
        out.extend(ups);
    }
    if limit > 0 {
        out.truncate(limit);
    }
    out
}

/// Answer a cluster-level `{"cmd":"trace"}` query: the proxy's own ring
/// filtered by the query, every healthy backend's ring fanned out to
/// concurrently, and the results stitched into cross-process timelines
/// (see [`stitch`]).
fn stitched_traces_json(cluster: &Cluster, json: &Json) -> String {
    let q = trace_query_of(json);
    let local = cluster.tracer.query(q.min_us, q.model.as_deref(), q.scheme.as_deref(), q.limit);
    let healthy: Vec<&Arc<Backend>> = cluster.backends.iter().filter(|b| b.is_healthy()).collect();
    let upstream: Vec<(String, Vec<Trace>)> = std::thread::scope(|scope| {
        let fetches: Vec<_> = healthy
            .iter()
            .map(|b| scope.spawn(|| b.fetch_traces(&q).map(|ts| (b.addr().to_string(), ts))))
            .collect();
        fetches
            .into_iter()
            .filter_map(|f| f.join().ok().flatten())
            .collect()
    });
    let stitched = stitch(&local, &upstream, q.limit);
    Json::obj(vec![
        ("count", Json::Num(stitched.len() as f64)),
        ("traces", Json::Arr(stitched)),
    ])
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn route_key_groups_configurations_and_auto_traffic() {
        let concrete = Json::parse(
            "{\"id\":1,\"model\":\"fashion_mlp\",\"k\":4,\"scheme\":\"dither\",\"pixels\":[]}",
        )
        .unwrap();
        assert_eq!(route_key(&concrete), "fashion_mlp/dither/k=4");
        // The legacy "mode" alias routes like "scheme".
        let alias = Json::parse("{\"model\":\"fashion_mlp\",\"k\":4,\"mode\":\"dither\"}").unwrap();
        assert_eq!(route_key(&alias), route_key(&concrete));
        // Auto spellings — "scheme":"auto" and "k":0 — share the model's
        // auto key, no matter what concrete fields ride along.
        let auto = Json::parse("{\"model\":\"fashion_mlp\",\"scheme\":\"auto\",\"max_mse\":0.5}")
            .unwrap();
        let k0 = Json::parse("{\"model\":\"fashion_mlp\",\"k\":0,\"scheme\":\"dither\"}").unwrap();
        assert_eq!(route_key(&auto), "fashion_mlp/auto");
        assert_eq!(route_key(&k0), "fashion_mlp/auto");
        // Model is part of every key.
        let other = Json::parse("{\"model\":\"digits_linear\",\"k\":4,\"scheme\":\"dither\"}")
            .unwrap();
        assert_ne!(route_key(&other), route_key(&concrete));
    }

    #[test]
    fn empty_backend_list_is_refused() {
        let cfg = ProxyConfig::default();
        let err = run_proxy(&cfg).unwrap_err().to_string();
        assert!(err.contains("hash ring cannot be empty"), "{err}");
    }

    fn trace(id: u64, model: &str) -> Trace {
        Trace {
            trace_id: id,
            request_id: id,
            model: model.to_string(),
            scheme: "dither".to_string(),
            k: 4,
            shard: None,
            total_us: 100,
            sampled: true,
            slow: false,
            spans: Vec::new(),
        }
    }

    #[test]
    fn stitch_attaches_upstream_timelines_and_keeps_orphans() {
        let local = vec![trace(0xA, "digits_linear"), trace(0xB, "digits_linear")];
        let upstream = vec![
            ("127.0.0.1:7801".to_string(), vec![trace(0xA, "digits_linear")]),
            ("127.0.0.1:7802".to_string(), vec![trace(0xC, "fashion_mlp")]),
        ];
        let out = stitch(&local, &upstream, 0);
        assert_eq!(out.len(), 3, "2 proxy traces + 1 orphaned backend trace");
        // Trace 0xA carries its backend timeline, tagged with the address.
        let a = &out[0];
        let ups = a.get("upstream").and_then(Json::as_arr).expect("stitched upstream array");
        assert_eq!(ups.len(), 1);
        assert_eq!(
            ups[0].get("backend").and_then(Json::as_str),
            Some("127.0.0.1:7801"),
            "upstream timeline names its serving backend"
        );
        // Trace 0xB matched nothing upstream: no upstream array.
        assert!(out[1].get("upstream").is_none());
        // The orphan (0xC) rides standalone, still backend-tagged.
        assert_eq!(out[2].get("backend").and_then(Json::as_str), Some("127.0.0.1:7802"));
        // The limit caps the stitched list.
        assert_eq!(stitch(&local, &upstream, 1).len(), 1);
        // Stitched output still round-trips through the reply parser.
        let line = Json::obj(vec![
            ("count", Json::Num(out.len() as f64)),
            ("traces", Json::Arr(out)),
        ])
        .to_string();
        let parsed = crate::coordinator::protocol::parse_traces(&line).unwrap();
        assert_eq!(parsed.len(), 3);
        assert_eq!(parsed[0].trace_id, 0xA);
    }

    #[test]
    fn trace_query_of_reads_filters_and_defaults() {
        let json = Json::parse(
            "{\"cmd\":\"trace\",\"min_us\":250,\"model\":\"fashion_mlp\",\
             \"scheme\":\"tpdf\",\"limit\":5}",
        )
        .unwrap();
        let q = trace_query_of(&json);
        assert_eq!(q.min_us, 250);
        assert_eq!(q.model.as_deref(), Some("fashion_mlp"));
        assert_eq!(q.scheme.as_deref(), Some("tpdf"));
        assert_eq!(q.limit, 5);
        let bare = Json::parse("{\"cmd\":\"trace\"}").unwrap();
        assert_eq!(trace_query_of(&bare), TraceQuery::default());
    }

    #[test]
    fn bucketless_backends_keep_percentiles_as_upper_bounds() {
        // A legacy (bucket-less) backend contributes its own percentiles;
        // a histogram backend contributes buckets. The merge must take the
        // max of the two views, and an empty merge must stay finite zeros.
        let empty = merge_summaries(&[]);
        assert_eq!(empty.total.p99_us, 0.0);
        assert_eq!(empty.reporting, 0);
        assert!(empty.bucket_sum.iter().all(|&b| b == 0));

        let legacy = StatsSummary {
            requests: 10,
            p50_us: 400.0,
            p95_us: 900.0,
            p99_us: 9_000.0,
            ..StatsSummary::default()
        };
        let mut bucketed = StatsSummary {
            requests: 10,
            latency_buckets: vec![0; BUCKETS],
            kernel: Some("wide".to_string()),
            ..StatsSummary::default()
        };
        bucketed.latency_buckets[3] = 10; // all ten requests in 4..=7 µs
        let m = merge_summaries(&[legacy.clone(), bucketed.clone()]);
        assert_eq!(m.total.requests, 20);
        assert_eq!(
            m.total.p99_us, 9_000.0,
            "legacy percentile dominates the merged-histogram estimate"
        );
        assert!(m.total.p50_us >= 400.0);
        assert_eq!(m.kernel.as_deref(), Some("wide"));
        // Histogram-only merge: percentiles come from the summed buckets.
        let hist_only = merge_summaries(std::slice::from_ref(&bucketed));
        assert_eq!(hist_only.total.p99_us, crate::coordinator::metrics::bucket_upper(3) as f64);
        // Legacy-only merge: no buckets at all, percentiles are the maxima.
        let legacy_only = merge_summaries(std::slice::from_ref(&legacy));
        assert_eq!(legacy_only.total.p50_us, 400.0);
        assert_eq!(legacy_only.total.p99_us, 9_000.0);
    }

    #[test]
    fn kernel_consensus_reports_mixed_fleets() {
        let wide = StatsSummary {
            kernel: Some("wide".to_string()),
            ..StatsSummary::default()
        };
        let scalar = StatsSummary {
            kernel: Some("scalar".to_string()),
            ..StatsSummary::default()
        };
        assert_eq!(
            merge_summaries(&[wide.clone(), wide.clone()]).kernel.as_deref(),
            Some("wide")
        );
        assert_eq!(merge_summaries(&[wide, scalar]).kernel.as_deref(), Some("mixed"));
        assert_eq!(merge_summaries(&[StatsSummary::default()]).kernel, None);
    }
}
