//! L4 cluster front tier: scale the sharded server past one process.
//!
//! PR 1's shard abstraction hash-routes *inside* one process, so capacity
//! is capped by a single process's cores. This subsystem lifts the same
//! routing one level up: a `proxy` front tier accepts the unchanged line
//! protocol, routes each request by its model/configuration key over a
//! consistent-hash ring ([`ring`], virtual nodes, minimal remapping) to
//! one of N backend `serve` processes, and speaks the pipelined protocol
//! upstream through per-backend pooled connections with in-flight windows
//! and out-of-order reply reassembly ([`backend`]). Health checking
//! ([`health`]) marks dead backends down — their keys deterministically
//! fail over to the next live ring member — and back up with exponential
//! probe backoff. The proxy's `stats` merges every backend's counters and
//! `fidelity` blocks ([`proxy`]), so the auto-precision view converges
//! cluster-wide.
//!
//! Clients need no changes: the proxy is just another server speaking the
//! same protocol, and deterministic replies through it are bit-identical
//! to a direct backend connection (locked by `tests/cluster_proxy.rs`).

pub mod backend;
pub mod health;
pub mod proxy;
pub mod ring;

pub use backend::{Backend, ForwardError};
pub use health::{health_loop, HealthPolicy};
pub use proxy::{run_proxy, ProxyConfig};
pub use ring::{key_hash, HashRing, DEFAULT_REPLICAS};
