//! Quantized-matmul benchmarks: throughput of the three rounding placements
//! × three rounding modes, vs the exact f64 matmul baseline. The perf-pass
//! probes for the §VII–§VIII engines.
//!
//! Run: `cargo bench --bench bench_matmul`

use dither::kernels::{self, KernelId};
use dither::linalg::{quant_matmul, Matrix, QuantMatmulConfig, Variant};
use dither::rounding::SchemeId;
use dither::util::benchmark::{black_box, Bench};
use dither::util::rng::Xoshiro256pp;

fn main() {
    let mut bench = Bench::new();
    let dim = 100usize;
    let mut rng = Xoshiro256pp::new(7);
    let a = Matrix::random_uniform(dim, dim, 0.0, 1.0, &mut rng);
    let b = Matrix::random_uniform(dim, dim, 0.0, 1.0, &mut rng);
    let flops = (2 * dim * dim * dim) as f64;

    bench.bench_items(&format!("matmul/f64_exact/{dim}^3"), flops, || {
        black_box(a.matmul(&b))
    });

    let mut seed = 0u64;
    for variant in Variant::ALL {
        for mode in SchemeId::PAPER {
            let name = format!("matmul/{}/{}/{dim}^3", variant.name(), mode.wire_name());
            bench.bench_items(&name, flops, || {
                seed += 1;
                let cfg = QuantMatmulConfig::unit(4, mode, variant, seed);
                black_box(quant_matmul(&a, &b, &cfg))
            });
        }
    }

    // Scalar vs wide kernel A/B: the same f64 matmul and one quantized
    // configuration under each process-wide kernel selection. The outputs
    // are bit-identical across kernels; only the throughput moves.
    let selected = kernels::active_id();
    for id in KernelId::ALL {
        kernels::select(id);
        let kn = id.name();
        bench.bench_items(&format!("kernel/{kn}/matmul/{dim}^3"), flops, || {
            black_box(a.matmul(&b))
        });
        bench.bench_items(&format!("kernel/{kn}/separate/dither/{dim}^3"), flops, || {
            seed += 1;
            let cfg = QuantMatmulConfig::unit(4, SchemeId::Dither, Variant::Separate, seed);
            black_box(quant_matmul(&a, &b, &cfg))
        });
    }
    kernels::select(selected);

    bench
        .write_json("results/bench_matmul.json")
        .expect("write bench json");
}
