//! Quantized-matmul benchmarks: throughput of the three rounding placements
//! × three rounding modes, vs the exact f64 matmul baseline. The perf-pass
//! probes for the §VII–§VIII engines.
//!
//! Run: `cargo bench --bench bench_matmul`

use dither::linalg::{quant_matmul, Matrix, QuantMatmulConfig, Variant};
use dither::rounding::SchemeId;
use dither::util::benchmark::{black_box, Bench};
use dither::util::rng::Xoshiro256pp;

fn main() {
    let mut bench = Bench::new();
    let dim = 100usize;
    let mut rng = Xoshiro256pp::new(7);
    let a = Matrix::random_uniform(dim, dim, 0.0, 1.0, &mut rng);
    let b = Matrix::random_uniform(dim, dim, 0.0, 1.0, &mut rng);
    let flops = (2 * dim * dim * dim) as f64;

    bench.bench_items(&format!("matmul/f64_exact/{dim}^3"), flops, || {
        black_box(a.matmul(&b))
    });

    let mut seed = 0u64;
    for variant in Variant::ALL {
        for mode in SchemeId::PAPER {
            let name = format!("matmul/{}/{}/{dim}^3", variant.name(), mode.wire_name());
            bench.bench_items(&name, flops, || {
                seed += 1;
                let cfg = QuantMatmulConfig::unit(4, mode, variant, seed);
                black_box(quant_matmul(&a, &b, &cfg))
            });
        }
    }

    bench
        .write_json("results/bench_matmul.json")
        .expect("write bench json");
}
