//! Bitstream hot-path benchmarks: encoding and arithmetic throughput per
//! scheme. These are the perf-pass probes for the §II–§IV substrate
//! (results logged in EXPERIMENTS.md §Perf).
//!
//! Run: `cargo bench --bench bench_bitstream`

use dither::bitstream::{average, multiply, represent, BitSeq, Scheme};
use dither::kernels::{self, KernelId};
use dither::util::benchmark::{black_box, Bench};
use dither::util::rng::Xoshiro256pp;

fn main() {
    let mut bench = Bench::new();
    let mut rng = Xoshiro256pp::new(42);

    for n in [64usize, 1024, 16384] {
        for scheme in Scheme::ALL {
            let name = format!("bitstream/encode/{}/N={n}", scheme.name());
            let mut x = 0.1f64;
            bench.bench_items(&name, n as f64, || {
                x = (x + 0.137).fract();
                black_box(represent(scheme, x, n, &mut rng))
            });
        }
    }

    for n in [1024usize, 16384] {
        for scheme in Scheme::ALL {
            let name = format!("bitstream/multiply/{}/N={n}", scheme.name());
            bench.bench_items(&name, n as f64, || {
                black_box(multiply(scheme, 0.371, 0.816, n, &mut rng))
            });
            let name = format!("bitstream/average/{}/N={n}", scheme.name());
            bench.bench_items(&name, n as f64, || {
                black_box(average(scheme, 0.371, 0.816, n, &mut rng))
            });
        }
    }

    // Raw word-parallel ops (roofline reference for the encoders).
    let n = 16384;
    let a = BitSeq::from_fn(n, |i| i % 3 == 0);
    let b = BitSeq::from_fn(n, |i| i % 5 == 0);
    bench.bench_items(&format!("bitstream/raw_and/N={n}"), n as f64, || {
        black_box(a.and(&b).count_ones())
    });
    bench.bench_items(&format!("bitstream/raw_popcount/N={n}"), n as f64, || {
        black_box(a.count_ones())
    });

    // Scalar vs wide kernel A/B on the word-level hot primitives, driven
    // through `kernels::get` so both variants run regardless of the
    // process-wide selection. `and_popcount` is the headline: the scalar
    // kernel reproduces the pre-kernel-layer path (allocate the AND
    // result, popcount it in a second pass) while the wide kernel fuses
    // the two over unrolled word lanes.
    let aw = a.words().to_vec();
    let bw = b.words().to_vec();
    let mut out = vec![0u64; aw.len()];
    for id in KernelId::ALL {
        let kern = kernels::get(id);
        let kn = id.name();
        bench.bench_items(&format!("kernel/{kn}/popcount/N={n}"), n as f64, || {
            black_box(kern.popcount_words(&aw))
        });
        bench.bench_items(&format!("kernel/{kn}/and/N={n}"), n as f64, || {
            kern.and_words(&aw, &bw, &mut out);
            black_box(out[0])
        });
        bench.bench_items(&format!("kernel/{kn}/and_popcount/N={n}"), n as f64, || {
            black_box(kern.and_popcount(&aw, &bw))
        });
    }

    bench
        .write_json("results/bench_bitstream.json")
        .expect("write bench json");
}
