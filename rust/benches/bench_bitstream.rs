//! Bitstream hot-path benchmarks: encoding and arithmetic throughput per
//! scheme. These are the perf-pass probes for the §II–§IV substrate
//! (results logged in EXPERIMENTS.md §Perf).
//!
//! Run: `cargo bench --bench bench_bitstream`

use dither::bitstream::{average, multiply, represent, BitSeq, Scheme};
use dither::util::benchmark::{black_box, Bench};
use dither::util::rng::Xoshiro256pp;

fn main() {
    let mut bench = Bench::new();
    let mut rng = Xoshiro256pp::new(42);

    for n in [64usize, 1024, 16384] {
        for scheme in Scheme::ALL {
            let name = format!("bitstream/encode/{}/N={n}", scheme.name());
            let mut x = 0.1f64;
            bench.bench_items(&name, n as f64, || {
                x = (x + 0.137).fract();
                black_box(represent(scheme, x, n, &mut rng))
            });
        }
    }

    for n in [1024usize, 16384] {
        for scheme in Scheme::ALL {
            let name = format!("bitstream/multiply/{}/N={n}", scheme.name());
            bench.bench_items(&name, n as f64, || {
                black_box(multiply(scheme, 0.371, 0.816, n, &mut rng))
            });
            let name = format!("bitstream/average/{}/N={n}", scheme.name());
            bench.bench_items(&name, n as f64, || {
                black_box(average(scheme, 0.371, 0.816, n, &mut rng))
            });
        }
    }

    // Raw word-parallel ops (roofline reference for the encoders).
    let n = 16384;
    let a = BitSeq::from_fn(n, |i| i % 3 == 0);
    let b = BitSeq::from_fn(n, |i| i % 5 == 0);
    bench.bench_items(&format!("bitstream/raw_and/N={n}"), n as f64, || {
        black_box(a.and(&b).count_ones())
    });
    bench.bench_items(&format!("bitstream/raw_popcount/N={n}"), n as f64, || {
        black_box(a.count_ones())
    });

    bench
        .write_json("results/bench_bitstream.json")
        .expect("write bench json");
}
