//! Ablation benches for the implementation choices DESIGN.md documents:
//!
//! 1. **Residual sampling** (§II-D construction): iid Bernoulli(δ) (the
//!    paper's literal text) vs systematic/stratified sampling (our default)
//!    — EMSE of representation, multiply, average.
//! 2. **Dither position alignment** in once-quantized matmuls: per-line
//!    rotation (our default) vs a single shared phase vs iid positions —
//!    matmul Frobenius error (shows why the alignment matters).
//! 3. **Dither period N** sensitivity for the rounding path.
//!
//! Run: `cargo bench --bench bench_ablation`

use dither::bitstream::{
    BitSeq, DitherEncoder, EvalConfig, Op, ResidualSampling,
};
use dither::linalg::{frobenius_error, quant_matmul, Matrix, QuantMatmulConfig, Variant};
use dither::rounding::SchemeId;
use dither::util::rng::Xoshiro256pp;
use dither::util::stats::Welford;

fn main() {
    residual_sampling_ablation();
    period_sensitivity();
    placement_vs_error();
}

/// Ablation 1: iid vs systematic residual sampling.
fn residual_sampling_ablation() {
    println!("== ablation: dither residual sampling (iid vs systematic) ==\n");
    println!(
        "  {:>10} {:>6} {:>14} {:>14}  ratio",
        "op", "N", "iid EMSE", "systematic"
    );
    let cfg = EvalConfig {
        pairs: 100,
        trials: 150,
        seed: 0xAB1A,
    };
    let pairs = cfg.draw_pairs();
    for op in Op::ALL {
        for &n in &[64usize, 256] {
            let emse = |residual: ResidualSampling| -> f64 {
                let mut total = 0.0;
                for (pi, &(x, y)) in pairs.iter().enumerate() {
                    let mut rng = Xoshiro256pp::new(cfg.seed ^ (pi as u64) << 16);
                    let truth = op.truth(x, y);
                    let mut w = Welford::new();
                    for _ in 0..cfg.trials {
                        let enc_x = DitherEncoder::prefix().with_residual(residual);
                        let enc_y = DitherEncoder::spread().with_residual(residual);
                        let est = match op {
                            Op::Represent => enc_x.encode(x, n, &mut rng).value(),
                            Op::Multiply => {
                                let a = enc_x.encode(x, n, &mut rng);
                                let b = enc_y.encode(y, n, &mut rng);
                                a.and(&b).value()
                            }
                            Op::Average => {
                                let a = enc_x.encode(x, n, &mut rng);
                                let b = enc_x.encode(y, n, &mut rng);
                                let w_seq = enc_x.control(n, &mut rng);
                                BitSeq::mux(&w_seq, &a, &b).value()
                            }
                        };
                        w.push((est - truth) * (est - truth));
                    }
                    total += w.mean();
                }
                total / pairs.len() as f64
            };
            let iid = emse(ResidualSampling::Iid);
            let sys = emse(ResidualSampling::Systematic);
            println!(
                "  {:>10} {:>6} {:>14.3e} {:>14.3e}  {:.2}x",
                op.name(),
                n,
                iid,
                sys,
                iid / sys
            );
        }
    }
    println!();
}

/// Ablation 3: dither period N for quantized matmul (per-partial).
fn period_sensitivity() {
    println!("== ablation: dither period N (per-partial matmul, k=2) ==\n");
    let dim = 48;
    let mut rng = Xoshiro256pp::new(5);
    let a = Matrix::random_uniform(dim, dim, 0.0, 0.5, &mut rng);
    let b = Matrix::random_uniform(dim, dim, 0.0, 0.5, &mut rng);
    let c = a.matmul(&b);
    println!("  {:>6} {:>12}", "N", "mean e_f");
    for &n in &[4usize, 16, 48, 128] {
        let mut err = 0.0;
        for t in 0..6u64 {
            let cfg = QuantMatmulConfig {
                n_a: Some(n),
                n_b: Some(n),
                ..QuantMatmulConfig::unit(2, SchemeId::Dither, Variant::PerPartial, 30 + t)
            };
            err += frobenius_error(&c, &quant_matmul(&a, &b, &cfg)) / 6.0;
        }
        println!("  {n:>6} {err:>12.4}");
    }
    println!("\n  (N = per-element use count — here {dim} — is the natural choice;");
    println!("   larger N cannot be swept within one matmul, smaller N re-uses σ)\n");
}

/// Ablation 2 proxy: how much each placement gains for each scheme.
fn placement_vs_error() {
    println!("== ablation: rounding placement x scheme (k=2, 48x48, e_f) ==\n");
    let dim = 48;
    let mut rng = Xoshiro256pp::new(9);
    let a = Matrix::random_uniform(dim, dim, 0.0, 1.0, &mut rng);
    let b = Matrix::random_uniform(dim, dim, 0.0, 1.0, &mut rng);
    let c = a.matmul(&b);
    print!("  {:>14}", "");
    for variant in Variant::ALL {
        print!(" {:>13}", variant.name());
    }
    println!();
    for mode in SchemeId::PAPER {
        print!("  {:>14}", mode.wire_name());
        for variant in Variant::ALL {
            let mut err = 0.0;
            for t in 0..6u64 {
                let cfg = QuantMatmulConfig::unit(2, mode, variant, 60 + t);
                err += frobenius_error(&c, &quant_matmul(&a, &b, &cfg)) / 6.0;
            }
            print!(" {err:>13.4}");
        }
        println!();
    }
    println!("\n  (per-partial buys the unbiased schemes the §VII averaging;");
    println!("   deterministic rounding cannot benefit — same bits every use)");
}
