//! End-to-end serving benchmark: batched quantized inference through the
//! PJRT artifact path (the L3→L2→L1 request path), plus the native-Rust
//! engine for comparison. Reported in EXPERIMENTS.md §Perf.
//!
//! Requires `make artifacts`. Run: `cargo bench --bench bench_e2e`

use dither::coordinator::Engine;
use dither::data::{Dataset, Task};
use dither::linalg::Variant;
use dither::nn::{quantized_predict, ActivationRanges, QuantInferenceConfig};
use dither::rounding::RoundingMode;
use dither::train::{trained_model, ModelSpec};
use dither::util::benchmark::{black_box, Bench};

fn main() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping bench_e2e: artifacts/manifest.json missing (run `make artifacts`)");
        return;
    }
    let mut bench = Bench::new();
    let engine = Engine::new("artifacts", 2000, 7).expect("engine");
    let ds = Dataset::synthesize(Task::Digits, 256, 99);

    for &batch in &[1usize, 32, 256] {
        let pixels: Vec<&[f64]> = (0..batch).map(|i| ds.images.row(i)).collect();
        // Warmup compiles the executable outside the timed region.
        let _ = engine
            .infer_batch("digits_linear", 4, RoundingMode::Dither, &pixels)
            .expect("warmup");
        let name = format!("e2e/pjrt_digits_linear/k=4/dither/batch={batch}");
        bench.bench_items(&name, batch as f64, || {
            black_box(
                engine
                    .infer_batch("digits_linear", 4, RoundingMode::Dither, &pixels)
                    .expect("infer"),
            )
        });
    }

    // Fashion MLP through PJRT.
    let fds = Dataset::synthesize(Task::Fashion, 32, 98);
    let pixels: Vec<&[f64]> = (0..32).map(|i| fds.images.row(i)).collect();
    let _ = engine
        .infer_batch("fashion_mlp", 4, RoundingMode::Dither, &pixels)
        .expect("warmup");
    bench.bench_items("e2e/pjrt_fashion_mlp/k=4/dither/batch=32", 32.0, || {
        black_box(
            engine
                .infer_batch("fashion_mlp", 4, RoundingMode::Dither, &pixels)
                .expect("infer"),
        )
    });

    // Native-Rust engine reference (same model, same batch).
    let (mlp, test, _) = trained_model(ModelSpec::DigitsLinear, 2000, 256, 7);
    let ranges = ActivationRanges::calibrate(&mlp, &test.images);
    let qcfg = QuantInferenceConfig {
        bits: 4,
        mode: RoundingMode::Dither,
        variant: Variant::Separate,
        seed: 3,
    };
    bench.bench_items("e2e/native_digits_linear/k=4/dither/batch=256", 256.0, || {
        black_box(quantized_predict(&mlp, &test.images, &ranges, &qcfg))
    });

    bench
        .write_json("results/bench_e2e.json")
        .expect("write bench json");
}
