//! End-to-end serving benchmarks: the native engine batch path, the
//! plan-cache hit-vs-miss comparison (the plan/execute split's headline
//! number), and the full TCP serving stack measured for 1 shard vs K
//! shards (the sharding speedup from the coordinator refactor) and for
//! lockstep vs pipelined connection driving (the protocol rework's
//! headline number: pipelining is what lets batches actually form).
//!
//! Run: `cargo bench --bench bench_e2e`   (`DITHER_BENCH_FAST=1` for a
//! smoke run). Results are written to `results/bench_e2e.json`.

use dither::cluster::{run_proxy, ProxyConfig};
use dither::coordinator::{
    format_request, format_watch, parse_watch_ack, ping, serve, wait_ready, Engine, ServerConfig,
    WatchQuery,
};
use dither::data::{Dataset, Task};
use dither::fidelity::{
    choose_slo, FidelityShard, LatencyView, SloBudget, LATENCY_MIN_SAMPLES,
};
use dither::rounding::SchemeId;
use dither::train::{ModelSpec, Zoo};
use dither::util::benchmark::{black_box, format_count, Bench};
use dither::util::json::Json;
use dither::util::threadpool::num_threads;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

const TRAIN_N: usize = 2000;

fn main() {
    let fast = std::env::var("DITHER_BENCH_FAST").is_ok();
    let mut bench = Bench::new();

    // ---- native engine batch throughput --------------------------------
    let zoo = Arc::new(Zoo::load(TRAIN_N, 7));
    let engine = Engine::from_zoo(zoo.clone(), 7);
    let ds = Dataset::synthesize(Task::Digits, 256, 99);
    for &batch in &[1usize, 32, 256] {
        let pixels: Vec<&[f64]> = (0..batch).map(|i| ds.images.row(i)).collect();
        let name = format!("e2e/engine_digits_linear/k=4/dither/batch={batch}");
        bench.bench_items(&name, batch as f64, || {
            black_box(
                engine
                    .infer_batch("digits_linear", 4, SchemeId::Dither, &pixels)
                    .expect("infer"),
            )
        });
    }
    let fds = Dataset::synthesize(Task::Fashion, 32, 98);
    let pixels: Vec<&[f64]> = (0..32).map(|i| fds.images.row(i)).collect();
    bench.bench_items("e2e/engine_fashion_mlp/k=4/dither/batch=32", 32.0, || {
        black_box(
            engine
                .infer_batch("fashion_mlp", 4, SchemeId::Dither, &pixels)
                .expect("infer"),
        )
    });

    // ---- kernel A/B on the engine batch path ---------------------------
    // The same engine and request shape under each process-wide kernel.
    // Deterministic replies are bit-identical across kernels (asserted
    // below), so the throughput delta is the whole kernel-layer story.
    let selected = dither::kernels::active_id();
    let ab_pixels: Vec<&[f64]> = (0..32).map(|i| ds.images.row(i)).collect();
    let mut kernel_logits: Vec<Vec<f64>> = Vec::new();
    for id in dither::kernels::KernelId::ALL {
        dither::kernels::select(id);
        let name = format!(
            "kernel/{}/e2e/digits_linear/k=4/deterministic/batch=32",
            id.name()
        );
        bench.bench_items(&name, 32.0, || {
            black_box(
                engine
                    .infer_batch("digits_linear", 4, SchemeId::Deterministic, &ab_pixels)
                    .expect("infer"),
            )
        });
        let outs = engine
            .infer_batch("digits_linear", 4, SchemeId::Deterministic, &ab_pixels)
            .expect("infer");
        kernel_logits.push(outs.into_iter().flat_map(|o| o.logits).collect());
    }
    for logits in &kernel_logits[1..] {
        assert_eq!(
            logits, &kernel_logits[0],
            "deterministic replies must be bit-identical across kernels"
        );
    }
    dither::kernels::select(selected);
    drop(engine);

    // ---- plan cache: hit vs miss ---------------------------------------
    // Same zoo, same requests; the only difference is whether the
    // weight-side plans are resident (prewarmed cache) or rebuilt per call
    // (capacity 0). The ratio is the serving win of the plan/execute
    // split.
    let hit_engine = Engine::from_zoo(zoo.clone(), 7);
    hit_engine.prewarm(&[4], &[SchemeId::Dither]);
    let miss_engine = Engine::with_plan_cache(zoo.clone(), 7, 0);
    let mut cache_pairs: Vec<(String, f64, f64)> = Vec::new();
    for &(model, batch) in &[("digits_linear", 1usize), ("fashion_mlp", 1), ("fashion_mlp", 8)] {
        let src = if model == "fashion_mlp" { &fds } else { &ds };
        let pixels: Vec<&[f64]> = (0..batch).map(|i| src.images.row(i % src.len())).collect();
        let engines: [(&Engine, &str); 2] = [(&hit_engine, "hit"), (&miss_engine, "miss")];
        let mut rates = [0.0f64; 2];
        for (slot, (engine, label)) in engines.iter().enumerate() {
            let name = format!("e2e/plan_cache_{label}/{model}/k=4/dither/batch={batch}");
            let result = bench.bench_items(&name, batch as f64, || {
                black_box(
                    engine
                        .infer_batch(model, 4, SchemeId::Dither, &pixels)
                        .expect("infer"),
                )
            });
            rates[slot] = result.throughput().unwrap_or(0.0);
        }
        cache_pairs.push((format!("{model}/batch={batch}"), rates[0], rates[1]));
    }
    for (case, hit, miss) in &cache_pairs {
        if *miss > 0.0 {
            println!("plan cache speedup {case}: {:.2}x (hit vs miss)", hit / miss);
        }
    }
    let hit_stats = hit_engine.plan_cache_stats();
    assert_eq!(hit_stats.misses, 0, "prewarmed engine must never replan");
    drop(miss_engine);

    // ---- shadow-sampling overhead --------------------------------------
    // Same prewarmed engine configuration; the shadowed variant re-runs
    // the exact f64 forward pass for every request row and records
    // per-logit errors. The ratio is the worst-case (rate 1.0) cost of
    // `--shadow-rate`; production rates are a few percent of it.
    let shadow_engine =
        Engine::from_zoo(zoo.clone(), 7).with_shadow(1.0, Arc::new(FidelityShard::new()));
    shadow_engine.prewarm(&[4], &[SchemeId::Dither]);
    let pixels32: Vec<&[f64]> = (0..32).map(|i| ds.images.row(i)).collect();
    let mut shadow_rates = [0.0f64; 2];
    let engines: [(&Engine, &str); 2] = [(&hit_engine, "off"), (&shadow_engine, "on")];
    for (slot, (engine, label)) in engines.iter().enumerate() {
        let name = format!("e2e/shadow_{label}/digits_linear/k=4/dither/batch=32");
        let result = bench.bench_items(&name, 32.0, || {
            black_box(
                engine
                    .infer_batch("digits_linear", 4, SchemeId::Dither, &pixels32)
                    .expect("infer"),
            )
        });
        shadow_rates[slot] = result.throughput().unwrap_or(0.0);
    }
    if shadow_rates[1] > 0.0 {
        println!(
            "shadow-rate 1.0 overhead: {:.2}x slower (items/s {:.0} -> {:.0}, {} logit errors recorded)",
            shadow_rates[0] / shadow_rates[1],
            shadow_rates[0],
            shadow_rates[1],
            shadow_engine.fidelity().total_samples()
        );
    }
    assert!(
        shadow_engine.fidelity().total_samples() > 0,
        "shadowed engine must record logit errors"
    );
    drop(hit_engine);
    drop(shadow_engine);

    // ---- scheme zoo: MSE vs throughput sweep ---------------------------
    // One entry per registered scheme at k=4: engine batch throughput on
    // the plan path next to the measured serving-granularity MSE from a
    // shadowed run — the fidelity/cost frontier the auto controller
    // navigates, with the literature zoo on it.
    let sweep_engine = Engine::from_zoo(zoo.clone(), 7);
    sweep_engine.prewarm(&[4], &SchemeId::ALL);
    let sweep_sink = Arc::new(FidelityShard::new());
    let sweep_shadowed = Engine::from_zoo(zoo.clone(), 7).with_shadow(1.0, sweep_sink.clone());
    let mse_rounds = if fast { 4 } else { 16 };
    let mut zoo_entries: Vec<Json> = Vec::new();
    for mode in SchemeId::ALL {
        let name = format!("e2e/scheme_zoo/{mode}/digits_linear/k=4/batch=32");
        let result = bench.bench_items(&name, 32.0, || {
            black_box(
                sweep_engine
                    .infer_batch("digits_linear", 4, mode, &pixels32)
                    .expect("infer"),
            )
        });
        for _ in 0..mse_rounds {
            sweep_shadowed
                .infer_batch("digits_linear", 4, mode, &pixels32)
                .expect("infer");
        }
        let est = sweep_sink.estimate(ModelSpec::DigitsLinear.index(), mode, 4);
        zoo_entries.push(Json::obj(vec![
            (
                "name",
                Json::Str(format!(
                    "e2e/scheme_zoo/{mode}/digits_linear/k=4/mse_vs_throughput"
                )),
            ),
            ("scheme", Json::Str(mode.to_string())),
            ("deterministic", Json::Bool(mode.is_deterministic())),
            ("items_per_s", Json::Num(result.throughput().unwrap_or(0.0))),
            ("mse", Json::Num(est.mse())),
            ("samples", Json::Num(est.samples as f64)),
        ]));
    }
    let measured_table = sweep_shadowed.fidelity_table();
    drop(sweep_engine);
    drop(sweep_shadowed);

    // ---- auto SLO resolution: the measured-cost controller -------------
    // Price the same dual-budget auto request against two synthetic
    // recent-latency phases over the *measured* fidelity table from the
    // shadowed sweep above: when dither measures fast the controller
    // serves it; when dither measures slow the identical budgets redirect
    // elsewhere. The bench entries are the per-request resolution cost —
    // the controller has to stay negligible next to one matmul.
    let slo_budget = SloBudget {
        max_mse: Some(1e9),
        max_latency_us: Some(10_000),
    };
    let slo_slot = ModelSpec::DigitsLinear.index();
    let mut auto_entries: Vec<Json> = Vec::new();
    let mut phase_schemes: Vec<String> = Vec::new();
    for (phase, dither_us, det_us) in
        [("dither_fast", 120u64, 90_000u64), ("dither_slow", 90_000, 120)]
    {
        let mut view = LatencyView::empty();
        view.set_scheme(SchemeId::Dither, LATENCY_MIN_SAMPLES, dither_us);
        view.set_model_k(slo_slot, 4, LATENCY_MIN_SAMPLES, dither_us);
        view.set_scheme(SchemeId::Deterministic, LATENCY_MIN_SAMPLES, det_us);
        view.set_model_k(slo_slot, 1, LATENCY_MIN_SAMPLES, det_us);
        let name = format!("e2e/auto_slo/resolve/{phase}/digits_linear");
        bench.bench_items(&name, 1.0, || {
            black_box(choose_slo(&measured_table, &view, slo_slot, slo_budget))
        });
        let choice = choose_slo(&measured_table, &view, slo_slot, slo_budget);
        println!(
            "auto_slo {phase}: ({}, k={}) measured={} predicted_latency_us={:?}",
            choice.scheme,
            choice.k,
            choice.any_measured(),
            choice.predicted_latency_us
        );
        phase_schemes.push(choice.scheme.to_string());
        auto_entries.push(Json::obj(vec![
            (
                "name",
                Json::Str(format!("e2e/auto_slo/choice/{phase}/digits_linear")),
            ),
            ("scheme", Json::Str(choice.scheme.to_string())),
            ("k", Json::Num(f64::from(choice.k))),
            ("measured", Json::Bool(choice.any_measured())),
            (
                "predicted_latency_us",
                Json::Num(choice.predicted_latency_us.map_or(-1.0, |v| v as f64)),
            ),
        ]));
    }
    assert_ne!(
        phase_schemes[0], phase_schemes[1],
        "the latency phases must steer the auto choice away from the static walk"
    );

    // ---- TCP serving throughput: 1 shard vs K shards -------------------
    // All lockstep (window 1): each connection waits for every reply.
    let k_shards = num_threads().clamp(2, 8);
    let requests = if fast { 240 } else { 2400 };
    let clients = 8;
    let mut serving = Vec::new();
    let mut lockstep_k_rps = 0.0f64;
    for (port, shards) in [(18011u16, 1usize), (18012, k_shards)] {
        let rps = serving_throughput(port, shards, clients, requests, &ds, 1, 0.0);
        let name = format!("e2e/serving/shards={shards}/k=4/dither");
        let throughput = format_count(rps);
        println!("{name:<56} {throughput:>12}/s  ({requests} reqs, {clients} clients)");
        if shards == k_shards {
            lockstep_k_rps = rps;
        }
        serving.push(Json::obj(vec![
            ("name", Json::Str(name)),
            ("shards", Json::Num(shards as f64)),
            ("requests", Json::Num(requests as f64)),
            ("clients", Json::Num(clients as f64)),
            ("items_per_s", Json::Num(rps)),
        ]));
    }
    if let (Some(one), Some(many)) = (serving.first(), serving.last()) {
        let a = one.get("items_per_s").and_then(Json::as_f64).unwrap_or(0.0);
        let b = many.get("items_per_s").and_then(Json::as_f64).unwrap_or(0.0);
        if a > 0.0 {
            println!(
                "serving speedup {k_shards} shards vs 1 shard: {:.2}x",
                b / a
            );
        }
    }

    // ---- pipelined vs lockstep serving ---------------------------------
    // Same server shape and request mix; the only difference is the
    // driving discipline: lockstep clients wait for every reply, the
    // pipelined run keeps a window of requests in flight per connection so
    // one client can fill a shard's batcher. Expect large gains at
    // batch-friendly load — batches actually form instead of serving a
    // procession of singletons.
    let window = 32usize;
    let pipelined_rps = serving_throughput(18013, k_shards, clients, requests, &ds, window, 0.0);
    let name = format!("e2e/serving_pipelined/shards={k_shards}/k=4/dither/window={window}");
    let throughput = format_count(pipelined_rps);
    println!("{name:<56} {throughput:>12}/s  ({requests} reqs, {clients} clients)");
    serving.push(Json::obj(vec![
        ("name", Json::Str(name)),
        ("shards", Json::Num(k_shards as f64)),
        ("requests", Json::Num(requests as f64)),
        ("clients", Json::Num(clients as f64)),
        ("window", Json::Num(window as f64)),
        ("items_per_s", Json::Num(pipelined_rps)),
    ]));
    let pipeline_speedup = if lockstep_k_rps > 0.0 {
        pipelined_rps / lockstep_k_rps
    } else {
        0.0
    };
    println!(
        "pipelined (window {window}) vs lockstep at {k_shards} shards: {pipeline_speedup:.2}x"
    );
    serving.push(Json::obj(vec![
        (
            "name",
            Json::Str(format!(
                "e2e/pipelined_vs_lockstep/shards={k_shards}/k=4/dither"
            )),
        ),
        ("lockstep_items_per_s", Json::Num(lockstep_k_rps)),
        ("pipelined_items_per_s", Json::Num(pipelined_rps)),
        ("window", Json::Num(window as f64)),
        ("speedup", Json::Num(pipeline_speedup)),
    ]));

    // ---- tracing overhead ----------------------------------------------
    // The same pipelined serving shape under three sampling rates. Rate 0
    // must sit within noise of the untraced pipelined number above
    // (`Tracer::begin` takes no clock reads when disabled); 0.01 is the
    // production-ish rate; 1.0 bounds the worst case, with every request
    // building a full span timeline and churning the ring buffer.
    let mut trace_meas: Vec<(f64, f64)> = Vec::new();
    for (port, rate) in [(18018u16, 0.0f64), (18019, 0.01), (18020, 1.0)] {
        let rps = serving_throughput(port, k_shards, clients, requests, &ds, window, rate);
        let name = format!(
            "e2e/trace_overhead/rate={rate}/shards={k_shards}/k=4/dither/window={window}"
        );
        println!(
            "{name:<56} {:>12}/s  ({requests} reqs, {clients} clients)",
            format_count(rps)
        );
        trace_meas.push((rate, rps));
        serving.push(Json::obj(vec![
            ("name", Json::Str(name)),
            ("trace_rate", Json::Num(rate)),
            ("shards", Json::Num(k_shards as f64)),
            ("requests", Json::Num(requests as f64)),
            ("clients", Json::Num(clients as f64)),
            ("window", Json::Num(window as f64)),
            ("items_per_s", Json::Num(rps)),
        ]));
    }
    let rate0_rps = trace_meas.first().map_or(0.0, |&(_, r)| r);
    let rate1_rps = trace_meas.last().map_or(0.0, |&(_, r)| r);
    if rate1_rps > 0.0 && pipelined_rps > 0.0 {
        println!(
            "trace overhead: rate 0 at {:.2}x of untraced, rate 1.0 at {:.2}x of rate 0",
            rate0_rps / pipelined_rps,
            rate1_rps / rate0_rps.max(1e-9)
        );
    }
    serving.push(Json::obj(vec![
        (
            "name",
            Json::Str(format!("e2e/trace_overhead_vs_untraced/shards={k_shards}")),
        ),
        ("untraced_items_per_s", Json::Num(pipelined_rps)),
        ("rate0_items_per_s", Json::Num(rate0_rps)),
        ("rate1_items_per_s", Json::Num(rate1_rps)),
        (
            "rate0_ratio",
            Json::Num(if pipelined_rps > 0.0 { rate0_rps / pipelined_rps } else { 0.0 }),
        ),
    ]));

    // ---- watch subscription overhead -----------------------------------
    // The same pipelined serving shape with N live `{"cmd":"watch"}`
    // subscriptions attached and a deliberately breaching SLO evaluator
    // publishing burn-rate events throughout. Events are control-plane
    // transitions, not per-request records, so subscribers must sit within
    // noise of the unwatched run — the acceptance bound is < 5% at one
    // subscriber.
    let mut watch_meas: Vec<(usize, f64)> = Vec::new();
    for (port, subs) in [(18021u16, 0usize), (18022, 1), (18023, 8)] {
        let rps = watched_throughput(port, k_shards, clients, requests, &ds, window, subs);
        let name = format!(
            "e2e/watch_overhead/subscribers={subs}/shards={k_shards}/k=4/dither/window={window}"
        );
        println!(
            "{name:<56} {:>12}/s  ({requests} reqs, {clients} clients)",
            format_count(rps)
        );
        watch_meas.push((subs, rps));
        serving.push(Json::obj(vec![
            ("name", Json::Str(name)),
            ("watch_subscribers", Json::Num(subs as f64)),
            ("shards", Json::Num(k_shards as f64)),
            ("requests", Json::Num(requests as f64)),
            ("clients", Json::Num(clients as f64)),
            ("window", Json::Num(window as f64)),
            ("items_per_s", Json::Num(rps)),
        ]));
    }
    let watch_base = watch_meas[0].1;
    if watch_base > 0.0 {
        println!(
            "watch overhead: 1 subscriber at {:.3}x of none, 8 subscribers at {:.3}x",
            watch_meas[1].1 / watch_base,
            watch_meas[2].1 / watch_base
        );
    }
    serving.push(Json::obj(vec![
        (
            "name",
            Json::Str(format!("e2e/watch_overhead_ratio/shards={k_shards}")),
        ),
        ("subs0_items_per_s", Json::Num(watch_base)),
        ("subs1_items_per_s", Json::Num(watch_meas[1].1)),
        ("subs8_items_per_s", Json::Num(watch_meas[2].1)),
        (
            "subs1_ratio",
            Json::Num(if watch_base > 0.0 { watch_meas[1].1 / watch_base } else { 0.0 }),
        ),
    ]));

    // ---- proxy over 2 backends vs direct -------------------------------
    // Same mixed-key workload (k ∈ {2,4,8} per client, so the hash ring
    // actually spreads keys over both backends) against (a) one direct
    // server with K shards and (b) a consistent-hash proxy fronting two
    // backends of K/2 shards each — equal core budget, one extra hop.
    let backend_shards = (k_shards / 2).max(1);
    let direct_addr = "127.0.0.1:18017";
    let direct_cfg = server_cfg(direct_addr, k_shards);
    let direct_server = std::thread::spawn(move || serve(&direct_cfg));
    assert!(wait_ready(direct_addr, Duration::from_secs(120)), "direct server up");
    let direct_rps = drive_mixed(direct_addr, clients, requests, &ds, 32);
    shutdown_addr(direct_addr);
    direct_server.join().expect("direct server thread").expect("direct server exits");

    let (b1_addr, b2_addr, proxy_addr) = ("127.0.0.1:18014", "127.0.0.1:18015", "127.0.0.1:18016");
    let (c1, c2) = (server_cfg(b1_addr, backend_shards), server_cfg(b2_addr, backend_shards));
    let backend1 = std::thread::spawn(move || serve(&c1));
    let backend2 = std::thread::spawn(move || serve(&c2));
    assert!(wait_ready(b1_addr, Duration::from_secs(120)), "backend 1 up");
    assert!(wait_ready(b2_addr, Duration::from_secs(120)), "backend 2 up");
    let proxy_cfg = ProxyConfig {
        addr: proxy_addr.to_string(),
        backends: vec![b1_addr.to_string(), b2_addr.to_string()],
        replicas: 64,
        backend_inflight: 256,
        probe_interval_ms: 200,
        probe_timeout_ms: 2_000,
        max_backoff_ms: 1_000,
        trace_rate: 0.0,
        trace_slow_us: 0,
        trace_buffer: 256,
    };
    let proxy = std::thread::spawn(move || run_proxy(&proxy_cfg));
    assert!(wait_ready(proxy_addr, Duration::from_secs(60)), "proxy up");
    let proxy_rps = drive_mixed(proxy_addr, clients, requests, &ds, 32);
    shutdown_addr(proxy_addr);
    proxy.join().expect("proxy thread").expect("proxy exits");
    shutdown_addr(b1_addr);
    shutdown_addr(b2_addr);
    backend1.join().expect("backend 1 thread").expect("backend 1 exits");
    backend2.join().expect("backend 2 thread").expect("backend 2 exits");

    let proxy_name =
        format!("e2e/serving_proxy/backends=2/shards={backend_shards}x2/mixed-k/window=32");
    println!(
        "{proxy_name:<56} {:>12}/s  ({requests} reqs, {clients} clients)",
        format_count(proxy_rps)
    );
    let proxy_ratio = if direct_rps > 0.0 { proxy_rps / direct_rps } else { 0.0 };
    println!(
        "proxy over 2x{backend_shards}-shard backends vs direct {k_shards}-shard: {proxy_ratio:.2}x"
    );
    serving.push(Json::obj(vec![
        ("name", Json::Str(proxy_name)),
        ("backends", Json::Num(2.0)),
        ("shards_per_backend", Json::Num(backend_shards as f64)),
        ("requests", Json::Num(requests as f64)),
        ("clients", Json::Num(clients as f64)),
        ("items_per_s", Json::Num(proxy_rps)),
    ]));
    serving.push(Json::obj(vec![
        (
            "name",
            Json::Str(format!(
                "e2e/proxy_vs_direct/backends=2/shards={backend_shards}x2/mixed-k"
            )),
        ),
        ("direct_items_per_s", Json::Num(direct_rps)),
        ("proxy_items_per_s", Json::Num(proxy_rps)),
        ("ratio", Json::Num(proxy_ratio)),
    ]));

    // Merge the harness results with the serving measurements and the
    // plan-cache speedup ratios.
    let mut all: Vec<Json> = Json::parse(&bench.to_json())
        .expect("bench json")
        .as_arr()
        .expect("bench json array")
        .to_vec();
    for (case, hit, miss) in &cache_pairs {
        all.push(Json::obj(vec![
            ("name", Json::Str(format!("e2e/plan_cache_speedup/{case}"))),
            ("hit_items_per_s", Json::Num(*hit)),
            ("miss_items_per_s", Json::Num(*miss)),
            ("speedup", Json::Num(if *miss > 0.0 { hit / miss } else { 0.0 })),
        ]));
    }
    let shadow_name = "e2e/shadow_rate_overhead/digits_linear/k=4/dither/batch=32";
    let overhead = if shadow_rates[1] > 0.0 {
        shadow_rates[0] / shadow_rates[1]
    } else {
        0.0
    };
    all.push(Json::obj(vec![
        ("name", Json::Str(shadow_name.to_string())),
        ("off_items_per_s", Json::Num(shadow_rates[0])),
        ("on_items_per_s", Json::Num(shadow_rates[1])),
        ("overhead_x", Json::Num(overhead)),
    ]));
    all.extend(zoo_entries);
    all.extend(auto_entries);
    all.extend(serving);
    std::fs::create_dir_all("results").expect("results dir");
    std::fs::write("results/bench_e2e.json", Json::Arr(all).to_string())
        .expect("write bench json");
}

/// The serving shape the proxy comparison uses for every process: mixed
/// prewarm so each client's bit width has resident plans, no shadow
/// sampling, generous queue.
fn server_cfg(addr: &str, shards: usize) -> ServerConfig {
    ServerConfig {
        addr: addr.to_string(),
        shards,
        max_batch: 32,
        max_wait_us: 500,
        queue_cap: 1024,
        train_n: TRAIN_N,
        seed: 7,
        prewarm_bits: vec![2, 4, 8],
        shadow_rate: 0.0,
        plan_cache_mb: 64,
        max_inflight: 512,
        reply_timeout_ms: 120_000,
        trace_rate: 0.0,
        trace_slow_us: 0,
        trace_buffer: 256,
        slo_p99_us: 0,
        slo_error_rate: 0.0,
        slo_mse_factor: 0.0,
        slo_eval_ms: 0,
    }
}

/// Graceful shutdown of a server or proxy at `addr`.
fn shutdown_addr(addr: &str) {
    let stream = TcpStream::connect(addr).expect("connect for shutdown");
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    writeln!(writer, "{{\"cmd\":\"shutdown\"}}").expect("shutdown");
    let mut line = String::new();
    let _ = reader.read_line(&mut line);
}

/// Drive `addr` with a windowed mixed-key workload: each client issues
/// dither requests at its own k ∈ {2, 4, 8}, so a consistent-hash front
/// tier spreads the keys over its backends. Overload bounces are resent
/// (they occupy no server work). Returns requests/second.
fn drive_mixed(addr: &str, clients: usize, requests: usize, ds: &Dataset, window: usize) -> f64 {
    let per_client = requests.div_ceil(clients);
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for c in 0..clients {
            let addr = addr.to_string();
            let img = ds.images.row(c % ds.len());
            scope.spawn(move || {
                let k = [2u32, 4, 8][c % 3];
                let stream = TcpStream::connect(&addr).expect("connect");
                stream.set_nodelay(true).ok();
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let mut writer = stream;
                let req = format_request(c as u64, "digits_linear", k, SchemeId::Dither, img);
                let mut line = String::new();
                let mut sent = 0usize;
                let mut recvd = 0usize;
                while recvd < per_client {
                    while sent < per_client && sent - recvd < window {
                        writeln!(writer, "{req}").expect("send");
                        sent += 1;
                    }
                    writer.flush().expect("flush");
                    line.clear();
                    reader.read_line(&mut line).expect("recv");
                    if line.contains("\"overloaded\":true") {
                        sent -= 1; // backpressure: resend in the next fill
                        continue;
                    }
                    assert!(!line.contains("\"error\""), "{line}");
                    recvd += 1;
                }
            });
        }
    });
    (per_client * clients) as f64 / t0.elapsed().as_secs_f64()
}

/// Start a server with `shards` shards, drive it with `clients` concurrent
/// connections issuing `requests` total k=4 dither requests, and return
/// the measured requests/second (excluding startup/teardown). `window` is
/// how many requests each connection keeps in flight: 1 is the lockstep
/// discipline (write, then wait for the reply), larger values pipeline.
#[allow(clippy::too_many_arguments)]
fn serving_throughput(
    port: u16,
    shards: usize,
    clients: usize,
    requests: usize,
    ds: &Dataset,
    window: usize,
    trace_rate: f64,
) -> f64 {
    let addr = format!("127.0.0.1:{port}");
    let cfg = ServerConfig {
        addr: addr.clone(),
        shards,
        max_batch: 32,
        max_wait_us: 500,
        queue_cap: 1024,
        train_n: TRAIN_N,
        seed: 7,
        prewarm_bits: vec![4],
        shadow_rate: 0.0,
        plan_cache_mb: 64,
        max_inflight: 64,
        reply_timeout_ms: 120_000,
        trace_rate,
        trace_slow_us: 0,
        // Big enough that ring eviction churn is part of the measured
        // cost, small enough to stay bounded at rate 1.0.
        trace_buffer: 1_024,
        slo_p99_us: 0,
        slo_error_rate: 0.0,
        slo_mse_factor: 0.0,
        slo_eval_ms: 0,
    };
    let server = std::thread::spawn(move || serve(&cfg));

    // Wait until the server answers a ping (the zoo may still be
    // loading). Bounded so a failed startup (e.g. port already in use)
    // aborts the bench instead of spinning forever.
    let mut ready = false;
    for _ in 0..600 {
        if server.is_finished() {
            break;
        }
        if ping(&addr) {
            ready = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(100));
    }
    if !ready {
        let err = server
            .join()
            .map(|r| r.err().map(|e| e.to_string()).unwrap_or_default())
            .unwrap_or_else(|_| "server thread panicked".to_string());
        panic!("server on {addr} never became ready: {err}");
    }

    let per_client = requests.div_ceil(clients);
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for c in 0..clients {
            let addr = addr.clone();
            let img = ds.images.row(c % ds.len());
            scope.spawn(move || {
                let stream = TcpStream::connect(&addr).expect("connect");
                stream.set_nodelay(true).ok();
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let mut writer = stream;
                let req = format_request(c as u64, "digits_linear", 4, SchemeId::Dither, img);
                let mut line = String::new();
                // Windowed send/recv: with window == 1 this is exactly the
                // old lockstep loop; larger windows keep the pipe full.
                let mut sent = 0usize;
                let mut recvd = 0usize;
                while recvd < per_client {
                    while sent < per_client && sent - recvd < window {
                        writeln!(writer, "{req}").expect("send");
                        sent += 1;
                    }
                    writer.flush().expect("flush");
                    line.clear();
                    reader.read_line(&mut line).expect("recv");
                    assert!(!line.contains("\"error\""), "{line}");
                    recvd += 1;
                }
            });
        }
    });
    let elapsed = t0.elapsed().as_secs_f64();

    // Graceful shutdown.
    let stream = TcpStream::connect(&addr).expect("connect for shutdown");
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    writeln!(writer, "{{\"cmd\":\"shutdown\"}}").expect("shutdown");
    let mut line = String::new();
    let _ = reader.read_line(&mut line);
    server.join().expect("server thread").expect("server exits cleanly");

    (per_client * clients) as f64 / elapsed
}

/// One live watch subscription against `addr`, drained on its own thread
/// until `stop` flips. The ack is awaited synchronously, so the
/// subscription provably exists before the measured window starts.
struct BenchWatcher {
    stop: Arc<AtomicBool>,
    handle: std::thread::JoinHandle<u64>,
}

fn attach_watcher(addr: &str) -> BenchWatcher {
    let stream = TcpStream::connect(addr).expect("watch connect");
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(Duration::from_millis(100))).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    writeln!(writer, "{}", format_watch(&WatchQuery::default())).expect("subscribe");
    // A read timeout can fire mid-line; read_line keeps accumulating into
    // the same buffer until the full ack lands.
    let mut line = String::new();
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        match reader.read_line(&mut line) {
            Ok(0) => panic!("watch connection closed before ack"),
            Ok(_) => break,
            Err(_) => assert!(Instant::now() < deadline, "watch ack never arrived"),
        }
    }
    parse_watch_ack(line.trim()).expect("watch ack");
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    let handle = std::thread::spawn(move || {
        let mut events = 0u64;
        let mut buf = String::new();
        while !stop2.load(Ordering::Acquire) {
            match reader.read_line(&mut buf) {
                Ok(0) => break,
                Ok(_) => {
                    events += 1;
                    buf.clear();
                }
                Err(_) => {}
            }
        }
        events
    });
    BenchWatcher { stop, handle }
}

/// Pipelined serving throughput with `watchers` live watch subscriptions
/// attached and a deliberately breaching SLO evaluator (1 µs p99 budget)
/// publishing events for the whole run — the live ops plane switched on.
/// Same traffic discipline as [`serving_throughput`].
#[allow(clippy::too_many_arguments)]
fn watched_throughput(
    port: u16,
    shards: usize,
    clients: usize,
    requests: usize,
    ds: &Dataset,
    window: usize,
    watchers: usize,
) -> f64 {
    let addr = format!("127.0.0.1:{port}");
    let cfg = ServerConfig {
        addr: addr.clone(),
        shards,
        max_batch: 32,
        max_wait_us: 500,
        queue_cap: 1024,
        train_n: TRAIN_N,
        seed: 7,
        prewarm_bits: vec![4],
        shadow_rate: 0.0,
        plan_cache_mb: 64,
        max_inflight: 64,
        reply_timeout_ms: 120_000,
        trace_rate: 0.0,
        trace_slow_us: 0,
        trace_buffer: 256,
        // Unmeetable budget: the evaluator fires (and holds) the burn-rate
        // alert under load, so watchers receive real event traffic.
        slo_p99_us: 1,
        slo_error_rate: 0.0,
        slo_mse_factor: 0.0,
        slo_eval_ms: 100,
    };
    let server = std::thread::spawn(move || serve(&cfg));
    assert!(wait_ready(&addr, Duration::from_secs(120)), "watched server up");
    let subs: Vec<BenchWatcher> = (0..watchers).map(|_| attach_watcher(&addr)).collect();

    let per_client = requests.div_ceil(clients);
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for c in 0..clients {
            let addr = addr.clone();
            let img = ds.images.row(c % ds.len());
            scope.spawn(move || {
                let stream = TcpStream::connect(&addr).expect("connect");
                stream.set_nodelay(true).ok();
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let mut writer = stream;
                let req = format_request(c as u64, "digits_linear", 4, SchemeId::Dither, img);
                let mut line = String::new();
                let mut sent = 0usize;
                let mut recvd = 0usize;
                while recvd < per_client {
                    while sent < per_client && sent - recvd < window {
                        writeln!(writer, "{req}").expect("send");
                        sent += 1;
                    }
                    writer.flush().expect("flush");
                    line.clear();
                    reader.read_line(&mut line).expect("recv");
                    assert!(!line.contains("\"error\""), "{line}");
                    recvd += 1;
                }
            });
        }
    });
    let elapsed = t0.elapsed().as_secs_f64();

    let mut delivered = 0u64;
    for watcher in subs {
        watcher.stop.store(true, Ordering::Release);
        delivered += watcher.handle.join().expect("watcher thread");
    }
    if watchers > 0 {
        println!(
            "watch_overhead subscribers={watchers}: {delivered} event lines delivered during the run"
        );
    }
    shutdown_addr(&addr);
    server.join().expect("server thread").expect("server exits cleanly");

    (per_client * clients) as f64 / elapsed
}
