//! Paper-table bench harness: regenerates EVERY table and figure of the
//! paper at bench scale (one section per table/figure; same code paths as
//! `dither experiment`, reduced settings so `cargo bench` stays minutes).
//!
//! Sections:
//!   figs 1-6  — EMSE/|bias| of repr/mult/avg vs N (§V)
//!   table I   — asymptotic slopes
//!   fig 8     — matmul e_f vs k
//!   figs 9-16 — quantized-inference accuracy mean/variance vs k
//!
//! Run: `cargo bench --bench bench_paper`
//! Full-scale equivalents: `dither experiment all --paper-scale`.

use dither::experiments::{run_experiment, ExperimentArgs};
use std::time::Instant;

fn main() {
    let args = ExperimentArgs {
        pairs: 60,
        trials: 60,
        ns: vec![8, 32, 128, 512],
        ks: vec![1, 2, 3, 4, 6, 8],
        matmul_pairs: 4,
        dim: 64,
        nn_trials: 4,
        train_n: 1200,
        test_n: 240,
        seed: 0xBE7C,
        out_dir: "results/bench".to_string(),
    };
    let t0 = Instant::now();
    for id in dither::experiments::EXPERIMENT_IDS {
        let t = Instant::now();
        run_experiment(id, &args).expect(id);
        println!(">> {id} regenerated in {:.2}s\n", t.elapsed().as_secs_f64());
    }
    println!(
        "== all {} paper results regenerated in {:.1}s (bench scale) ==",
        dither::experiments::EXPERIMENT_IDS.len(),
        t0.elapsed().as_secs_f64()
    );
}
