//! Property-based tests (via the in-tree `propcheck` mini-framework) on the
//! encoding, rounding, linalg and coordinator invariants.

use dither::bitstream::{
    encode_x, encode_y, BitSeq, DitherEncoder, DitherParams, Op, Scheme,
};
use dither::linalg::{quant_matmul, Matrix, QuantMatmulConfig, Variant};
use dither::rounding::{Quantizer, SchemeId, ScalarRounder};
use dither::util::json::Json;
use dither::util::propcheck::{check, check_with, Config, Gen, Pair, RangeUsize, UnitF64};
use dither::util::rng::Xoshiro256pp;

/// Generator for (value, sequence length).
fn value_and_len() -> Pair<UnitF64, RangeUsize> {
    Pair(UnitF64::unit(), RangeUsize { lo: 1, hi: 512 })
}

#[test]
fn prop_estimates_stay_in_unit_interval() {
    check(&value_and_len(), |&(x, n)| {
        let mut rng = Xoshiro256pp::new(x.to_bits() ^ n as u64);
        Scheme::ALL.iter().all(|&s| {
            let v = encode_x(s, x, n, &mut rng).value();
            (0.0..=1.0).contains(&v)
        })
    });
}

#[test]
fn prop_dither_params_invariants() {
    // For every (x, N): δ ∈ [0, min(1, 2/N)], E = x exactly, Var ≤ 2/N².
    check(&value_and_len(), |&(x, n)| {
        let p = DitherParams::of(x, n);
        let delta_ok = p.delta >= 0.0 && p.delta <= (2.0 / n as f64).min(1.0) + 1e-12;
        let exp_ok = (p.expectation(n) - x).abs() < 1e-9;
        let var_ok = p.variance(n) <= 2.0 / (n * n) as f64 + 1e-12;
        delta_ok && exp_ok && var_ok
    });
}

#[test]
fn prop_dither_error_bounded_by_one_pulse_plus_noise() {
    // Dither sample error: deterministic part within 1/N of x; stochastic
    // residue is Binomial(N, δ≤2/N)/N, so P(err > 10/N) is astronomically
    // small. Checked as a hard bound with slack.
    check(&value_and_len(), |&(x, n)| {
        let mut rng = Xoshiro256pp::new(2 ^ x.to_bits() ^ (n as u64) << 1);
        let enc = DitherEncoder::prefix();
        let v = enc.encode(x, n, &mut rng).value();
        (v - x).abs() <= 12.0 / n as f64 + 1e-9
    });
}

#[test]
fn prop_and_is_commutative_and_bounded() {
    check(
        &Pair(Pair(UnitF64::unit(), UnitF64::unit()), RangeUsize { lo: 1, hi: 256 }),
        |&((x, y), n)| {
            let mut rng = Xoshiro256pp::new(x.to_bits() ^ y.to_bits().rotate_left(17) ^ n as u64);
            let a = encode_x(Scheme::Dither, x, n, &mut rng);
            let b = encode_y(Scheme::Dither, y, n, &mut rng);
            let ab = a.and(&b);
            let ba = b.and(&a);
            // commutative, and Z_s ≤ min(X_s, Y_s) (AND can't create ones)
            ab == ba && ab.value() <= a.value().min(b.value()) + 1e-12
        },
    );
}

#[test]
fn prop_mux_value_between_operands() {
    // U_i selects per-pulse, so U_s ∈ [min(X_s,Y_s), max(X_s,Y_s)]… not in
    // general (mix of disjoint index sets), but it IS bounded by the
    // per-index envelope: U_s ≤ max over sequences' values + 1 pulse. We
    // check the always-true invariant: U_s ∈ [0,1] and the exact identity
    // U = W·X + (1-W)·Y per pulse.
    check(
        &Pair(Pair(UnitF64::unit(), UnitF64::unit()), RangeUsize { lo: 1, hi: 200 }),
        |&((x, y), n)| {
            let mut rng = Xoshiro256pp::new(4 ^ x.to_bits() ^ y.to_bits().rotate_left(23) ^ n as u64);
            let xs = encode_x(Scheme::Dither, x, n, &mut rng);
            let ys = encode_x(Scheme::Dither, y, n, &mut rng);
            let w = BitSeq::from_fn(n, |i| i % 2 == 0);
            let u = BitSeq::mux(&w, &xs, &ys);
            (0..n).all(|i| u.get(i) == if w.get(i) { xs.get(i) } else { ys.get(i) })
        },
    );
}

#[test]
fn prop_scalar_rounders_floor_or_ceil() {
    struct Alpha;
    impl Gen for Alpha {
        type Item = f64;
        fn gen(&self, rng: &mut Xoshiro256pp) -> f64 {
            rng.uniform(-100.0, 100.0)
        }
    }
    check(&Alpha, |&v| {
        SchemeId::ALL.iter().all(|&m| {
            let mut r = ScalarRounder::new(m, 32, 5);
            let out = r.round(v);
            out == v.floor() as i64 || out == v.ceil() as i64
        })
    });
}

#[test]
fn prop_quantizer_roundtrip_within_step() {
    check(
        &Pair(UnitF64 { lo: -1.0, hi: 1.0 }, RangeUsize { lo: 1, hi: 12 }),
        |&(v, k)| {
            let q = Quantizer::new(k as u32, -1.0, 1.0);
            let deq = q.dequant(q.quantize_round(v));
            (deq - v).abs() <= q.step() / 2.0 + 1e-9
        },
    );
}

#[test]
fn prop_quant_matmul_error_bounded_by_step_budget() {
    // |Ĉ - C|_∞ per entry ≤ q·(step_a + step_b + step_a·step_b) for any
    // mode/variant (each factor moves by at most one quantization step).
    let dims = RangeUsize { lo: 1, hi: 12 };
    check_with(
        Config {
            cases: 40,
            seed: 0xC0DE,
            max_shrink: 50,
        },
        &Pair(Pair(dims, RangeUsize { lo: 1, hi: 12 }), RangeUsize { lo: 1, hi: 6 }),
        |&((p, q), kbits)| {
            let mut rng = Xoshiro256pp::new((p * 31 + q) as u64);
            let a = Matrix::random_uniform(p, q, 0.0, 1.0, &mut rng);
            let b = Matrix::random_uniform(q, p, 0.0, 1.0, &mut rng);
            let c = a.matmul(&b);
            let step = 1.0 / ((1u32 << kbits) - 1).max(1) as f64;
            let budget = q as f64 * (2.0 * step + step * step) + 1e-9;
            Variant::ALL.iter().all(|&variant| {
                SchemeId::ALL.iter().all(|&mode| {
                    let cfg = QuantMatmulConfig::unit(kbits as u32, mode, variant, 1);
                    let c_hat = quant_matmul(&a, &b, &cfg);
                    c.sub(&c_hat).max_abs() <= budget
                })
            })
        },
    );
}

#[test]
fn prop_json_roundtrip_floats() {
    struct Floats;
    impl Gen for Floats {
        type Item = Vec<f64>;
        fn gen(&self, rng: &mut Xoshiro256pp) -> Vec<f64> {
            (0..rng.below(20)).map(|_| rng.uniform(-1e6, 1e6)).collect()
        }
    }
    check(&Floats, |xs| {
        let j = Json::nums(xs);
        let back = Json::parse(&j.to_string()).unwrap();
        let ys = back.as_f64_vec().unwrap();
        xs.iter().zip(&ys).all(|(a, b)| {
            (a - b).abs() <= a.abs().max(1.0) * 1e-12
        })
    });
}

/// The [`BitSeq`] contract: every bit at position >= len in the last word
/// is zero, so `count_ones` (a plain word-wise popcount) equals the
/// per-index count.
fn tail_invariant_holds(s: &BitSeq) -> bool {
    let n = s.len();
    let rem = n % 64;
    let tail_clean = if rem == 0 {
        true
    } else {
        s.words().last().map(|w| w & !((1u64 << rem) - 1) == 0).unwrap_or(true)
    };
    tail_clean
        && s.words().len() == n.div_ceil(64)
        && s.count_ones() == s.iter().filter(|&b| b).count() as u64
        && s.count_ones() <= n as u64
}

#[test]
fn prop_bitseq_ops_preserve_tail_invariant() {
    // Every constructor and word-parallel op must keep bits past `len`
    // zero — `ones` and `mux` write `u64::MAX` / `!w` patterns that would
    // leak into the tail without `mask_tail`.
    check(
        &Pair(RangeUsize { lo: 1, hi: 320 }, RangeUsize { lo: 0, hi: 1 << 20 }),
        |&(n, seed)| {
            let mut rng = Xoshiro256pp::new(seed as u64);
            let a = BitSeq::from_fn(n, |_| rng.bernoulli(0.5));
            let b = BitSeq::from_fn(n, |_| rng.bernoulli(0.3));
            let w = BitSeq::from_fn(n, |i| i % 3 == 0);
            tail_invariant_holds(&BitSeq::zeros(n))
                && tail_invariant_holds(&BitSeq::ones(n))
                && tail_invariant_holds(&a)
                && tail_invariant_holds(&a.and(&b))
                && tail_invariant_holds(&BitSeq::mux(&w, &a, &b))
                && tail_invariant_holds(&BitSeq::mux(&BitSeq::zeros(n), &a, &BitSeq::ones(n)))
        },
    );
}

#[test]
fn prop_bitseq_mask_tail_repairs_raw_word_writes() {
    // `words_mut` callers must restore the invariant with `mask_tail`; the
    // repaired sequence reads all-ones below `len` and nothing above.
    check(&RangeUsize { lo: 1, hi: 320 }, |&n| {
        let mut s = BitSeq::zeros(n);
        for w in s.words_mut() {
            *w = u64::MAX;
        }
        s.mask_tail();
        tail_invariant_holds(&s) && s.count_ones() == n as u64 && s.value() == 1.0
    });
}

/// Structured request-message fuzz case: each field independently valid or
/// invalid; `parse_message` must accept exactly the all-valid combinations.
#[derive(Debug, Clone)]
struct ReqCase {
    k: i64,
    scheme: usize,
    pixels: usize,
    with_pixels: bool,
}

const SCHEME_SPELLINGS: [&str; 12] = [
    "dither",
    "stochastic",
    "deterministic",
    "det",
    "sr",
    "traditional",
    "sr2",
    "srvb",
    "tpdf",
    "gauss",
    "fuzzy",
    "",
];
const VALID_SCHEMES: usize = 10;

struct ReqGen;
impl Gen for ReqGen {
    type Item = ReqCase;
    fn gen(&self, rng: &mut Xoshiro256pp) -> ReqCase {
        ReqCase {
            k: rng.below(24) as i64 - 4,
            scheme: rng.below(SCHEME_SPELLINGS.len() as u64) as usize,
            pixels: if rng.bernoulli(0.5) {
                784
            } else {
                rng.below(1000) as usize
            },
            with_pixels: rng.bernoulli(0.9),
        }
    }
}

#[test]
fn prop_protocol_accepts_exactly_the_valid_requests() {
    check(&ReqGen, |case| {
        let scheme = SCHEME_SPELLINGS[case.scheme];
        let mut line = format!("{{\"id\":1,\"k\":{},\"scheme\":\"{}\"", case.k, scheme);
        if case.with_pixels {
            line.push_str(",\"pixels\":[");
            line.push_str(&vec!["0.5"; case.pixels].join(","));
            line.push(']');
        }
        line.push('}');
        let should_parse = (1..=16).contains(&case.k)
            && case.scheme < VALID_SCHEMES
            && case.with_pixels
            && case.pixels == 784;
        match dither::coordinator::parse_message(&line) {
            Ok(dither::coordinator::Message::Infer(req)) => {
                should_parse && req.k == case.k as u32 && req.pixels.len() == 784
            }
            Ok(_) => false,
            Err(_) => !should_parse,
        }
    });
}

#[test]
fn prop_protocol_parse_never_panics_on_fuzz() {
    struct Garbage;
    impl Gen for Garbage {
        type Item = String;
        fn gen(&self, rng: &mut Xoshiro256pp) -> String {
            let len = rng.below(200) as usize;
            (0..len)
                .map(|_| {
                    let c = rng.below(96) as u8 + 32;
                    c as char
                })
                .collect()
        }
    }
    check(&Garbage, |s| {
        // Must return Ok or Err, never panic.
        let _ = dither::coordinator::parse_message(s);
        true
    });
}

#[test]
fn prop_protocol_request_format_parse_roundtrip() {
    // format_request → parse_message is lossless for every valid request
    // shape: id, model, k, scheme, and the 784 pixels (the JSON float
    // encoding prints shortest-roundtrip, so pixel equality is exact —
    // the serving bit-identity checks depend on that).
    use dither::coordinator::{format_request, parse_message, Message};

    #[derive(Debug, Clone)]
    struct RtCase {
        id: u64,
        model: usize,
        k: u32,
        mode: usize,
        seed: u64,
    }
    struct RtGen;
    impl Gen for RtGen {
        type Item = RtCase;
        fn gen(&self, rng: &mut Xoshiro256pp) -> RtCase {
            RtCase {
                id: rng.below(1 << 48),
                model: rng.below(2) as usize,
                k: 1 + rng.below(16) as u32,
                mode: rng.below(SchemeId::COUNT as u64) as usize,
                seed: rng.below(u64::MAX),
            }
        }
    }
    check_with(
        Config {
            cases: 64,
            seed: 0x51DE,
            max_shrink: 0,
        },
        &RtGen,
        |case| {
            let mut rng = Xoshiro256pp::new(case.seed);
            let pixels: Vec<f64> = (0..784).map(|_| rng.uniform(0.0, 1.0)).collect();
            let model = ["digits_linear", "fashion_mlp"][case.model];
            let mode = SchemeId::ALL[case.mode];
            let line = format_request(case.id, model, case.k, mode, &pixels);
            match parse_message(&line) {
                Ok(Message::Infer(r)) => {
                    r.id == case.id
                        && r.model == model
                        && r.k == case.k
                        && r.scheme == mode
                        && !r.auto
                        && r.max_mse.is_none()
                        && r.pixels == pixels
                }
                _ => false,
            }
        },
    );
}

#[test]
fn prop_protocol_auto_request_roundtrip() {
    // format_request_auto → parse_message preserves the id, model, and
    // error budget, and always marks the request auto.
    use dither::coordinator::{format_request_auto, parse_message, Message};
    check_with(
        Config {
            cases: 64,
            seed: 0xA072,
            max_shrink: 0,
        },
        &Pair(UnitF64 { lo: -6.0, hi: 6.0 }, RangeUsize { lo: 0, hi: 1 << 20 }),
        |&(log_budget, id)| {
            let budget = 10f64.powf(log_budget);
            let pixels = vec![0.25f64; 784];
            let line = format_request_auto(id as u64, "fashion_mlp", budget, &pixels);
            match parse_message(&line) {
                Ok(Message::Infer(r)) => {
                    r.auto
                        && r.id == id as u64
                        && r.model == "fashion_mlp"
                        && r.max_mse == Some(budget)
                }
                _ => false,
            }
        },
    );
}

#[test]
fn prop_protocol_auto_and_k_zero_shapes_accepted_exactly() {
    // The auto-request acceptance surface: `"scheme":"auto"` (k optional
    // and ignored) and `"k":0` (scheme ignored) both require at least one
    // budget — a positive finite max_mse, a positive integral
    // max_latency_us, or both — and every present budget must be valid;
    // everything else follows the fixed-request rules (budget fields
    // ignored).
    use dither::coordinator::{parse_message, Message};
    const K_SPELL: [&str; 4] = ["", "\"k\":0,", "\"k\":4,", "\"k\":99,"];
    const SCHEME_SPELL: [&str; 3] = ["auto", "dither", "fuzzy"];
    const BUDGET_SPELL: [&str; 5] = [
        "",
        "\"max_mse\":-1,",
        "\"max_mse\":0,",
        "\"max_mse\":0.25,",
        "\"max_mse\":1e999,",
    ];
    const LATENCY_SPELL: [&str; 4] = [
        "",
        "\"max_latency_us\":2500,",
        "\"max_latency_us\":0,",
        "\"max_latency_us\":-3,",
    ];
    check(
        &Pair(
            Pair(RangeUsize { lo: 0, hi: 3 }, RangeUsize { lo: 0, hi: 2 }),
            Pair(RangeUsize { lo: 0, hi: 4 }, RangeUsize { lo: 0, hi: 3 }),
        ),
        |&((k_kind, scheme_kind), (budget_kind, lat_kind))| {
            let pixels = vec!["0.5"; 784].join(",");
            let line = format!(
                "{{\"id\":9,{}{}{}\"scheme\":\"{}\",\"pixels\":[{}]}}",
                K_SPELL[k_kind],
                BUDGET_SPELL[budget_kind],
                LATENCY_SPELL[lat_kind],
                SCHEME_SPELL[scheme_kind],
                pixels
            );
            let auto = scheme_kind == 0 || k_kind == 1;
            let should_parse = if auto {
                // Every present budget must be valid, and at least one
                // axis must be present (a budget-less auto has no
                // resolvable meaning).
                let mse_ok = budget_kind == 0 || budget_kind == 3;
                let lat_ok = lat_kind == 0 || lat_kind == 1;
                mse_ok && lat_ok && (budget_kind == 3 || lat_kind == 1)
            } else {
                // Fixed request: k must be present and in range, and the
                // scheme spelling valid; the budget fields are ignored.
                k_kind == 2 && scheme_kind == 1
            };
            match parse_message(&line) {
                Ok(Message::Infer(r)) => {
                    should_parse
                        && r.auto == auto
                        && (!auto || r.max_mse == (budget_kind == 3).then_some(0.25))
                        && (!auto || r.max_latency_us == (lat_kind == 1).then_some(2500))
                        && (auto
                            || (r.k == 4 && r.max_mse.is_none() && r.max_latency_us.is_none()))
                }
                Ok(_) => false,
                Err(_) => !should_parse,
            }
        },
    );
}

#[test]
fn prop_protocol_response_shapes_echo_their_id() {
    // Every response shape — success, error, overload — parses back and
    // echoes the id it was built with; response_id extracts it, which is
    // what pipelined clients key on.
    use dither::coordinator::{format_error, format_overloaded, format_response, response_id};
    struct RespGen;
    #[derive(Debug, Clone)]
    struct RespCase {
        id: u64,
        pred: u8,
        mode: usize,
        k: u32,
        latency: u64,
        batch: usize,
        shard: usize,
        auto: bool,
        measured: bool,
        kind: usize,
    }
    impl Gen for RespGen {
        type Item = RespCase;
        fn gen(&self, rng: &mut Xoshiro256pp) -> RespCase {
            RespCase {
                id: rng.below(1 << 48),
                pred: rng.below(10) as u8,
                mode: rng.below(SchemeId::COUNT as u64) as usize,
                k: 1 + rng.below(16) as u32,
                latency: rng.below(1 << 30),
                batch: 1 + rng.below(64) as usize,
                shard: rng.below(16) as usize,
                auto: rng.bernoulli(0.5),
                measured: rng.bernoulli(0.5),
                kind: rng.below(3) as usize,
            }
        }
    }
    check(&RespGen, |c| {
        let mode = SchemeId::ALL[c.mode];
        let line = match c.kind {
            0 => {
                let logits: Vec<f64> = (0..10).map(|j| c.id as f64 * 0.5 + j as f64).collect();
                format_response(
                    c.id, c.pred, mode, c.k, &logits, c.latency, c.batch, c.shard, c.auto,
                    c.measured,
                )
            }
            1 => format_error(c.id, "some \"quoted\" failure\nwith newline", false),
            _ => format_overloaded(c.id),
        };
        let Ok(parsed) = Json::parse(&line) else {
            return false;
        };
        if response_id(&line) != Ok(c.id) {
            return false;
        }
        match c.kind {
            0 => {
                parsed.get("pred").and_then(Json::as_f64) == Some(f64::from(c.pred))
                    && parsed.get("scheme").and_then(Json::as_str) == Some(mode.wire_name())
                    && parsed.get("k").and_then(Json::as_f64) == Some(f64::from(c.k))
                    && parsed.get("latency_us").and_then(Json::as_f64) == Some(c.latency as f64)
                    && parsed.get("batch").and_then(Json::as_f64) == Some(c.batch as f64)
                    && parsed.get("shard").and_then(Json::as_f64) == Some(c.shard as f64)
                    && parsed.get("auto").and_then(Json::as_bool) == c.auto.then_some(true)
                    // "measured" only ever rides an auto reply: the
                    // non-auto wire shape is frozen.
                    && parsed.get("measured").and_then(Json::as_bool)
                        == (c.auto && c.measured).then_some(true)
                    && parsed.get("error").is_none()
            }
            1 => {
                parsed.get("error").and_then(Json::as_str).is_some()
                    && parsed.get("retryable").and_then(Json::as_bool) == Some(false)
                    && parsed.get("overloaded").is_none()
            }
            _ => {
                parsed.get("overloaded").and_then(Json::as_bool) == Some(true)
                    && parsed.get("error").and_then(Json::as_str) == Some("overloaded")
                    && parsed.get("retryable").and_then(Json::as_bool) == Some(true)
            }
        }
    });
}

#[test]
fn prop_scheme_names_roundtrip_through_stats_json() {
    // Every registered scheme's wire name survives a stats emit → parse
    // cycle: a fidelity cell keyed by the scheme's Display spelling
    // parses back to the same SchemeId for any sample count — the
    // contract the proxy's cross-node stats merge rests on.
    use dither::coordinator::parse_stats;
    check(
        &Pair(
            RangeUsize { lo: 0, hi: SchemeId::COUNT - 1 },
            RangeUsize { lo: 1, hi: 4096 },
        ),
        |&(slot, samples)| {
            let scheme = SchemeId::ALL[slot];
            let line = format!(
                "{{\"requests\":{samples},\"fidelity\":[{{\"model\":\"digits_linear\",\
                 \"scheme\":\"{scheme}\",\"k\":4,\"samples\":{samples},\
                 \"bias\":0.125,\"variance\":0.5}}]}}"
            );
            match parse_stats(&line) {
                Ok(s) => {
                    s.fidelity.len() == 1
                        && s.fidelity[0].scheme == scheme
                        && s.fidelity[0].k == 4
                        && s.fidelity[0].estimate.samples == samples as u64
                }
                Err(_) => false,
            }
        },
    );
}

#[test]
fn prop_unknown_scheme_rejection_echoes_id_and_is_not_retryable() {
    // The server answers an unknown-scheme request with the unified error
    // shape: the request id echoed (line_id digs it out of the rejected
    // line) and retryable:false — resending the same spelling can never
    // succeed. Checked over arbitrary ids and invalid spellings,
    // including near-misses of the zoo names.
    use dither::coordinator::{format_error, line_id, parse_message, response_id};
    struct BadScheme;
    impl Gen for BadScheme {
        type Item = (u64, String);
        fn gen(&self, rng: &mut Xoshiro256pp) -> (u64, String) {
            const BAD: [&str; 8] =
                ["fuzzy", "sr3", "srvb2", "tpdf_", "gaus", "auto ", "DITHER", "sto chastic"];
            let spelling = BAD[rng.below(BAD.len() as u64) as usize].to_string();
            (rng.below(1 << 48), spelling)
        }
    }
    check(&BadScheme, |(id, spelling)| {
        let pixels = vec!["0.5"; 784].join(",");
        let line =
            format!("{{\"id\":{id},\"k\":4,\"scheme\":\"{spelling}\",\"pixels\":[{pixels}]}}");
        let Err(e) = parse_message(&line) else {
            return false; // an invalid spelling must never parse
        };
        // The reply the serve loop builds for an unparseable line:
        let reply = format_error(line_id(&line), &e, false);
        let Ok(parsed) = Json::parse(&reply) else {
            return false;
        };
        response_id(&reply) == Ok(*id)
            && parsed.get("retryable").and_then(Json::as_bool) == Some(false)
            && parsed.get("error").and_then(Json::as_str).is_some()
    });
}

#[test]
fn prop_protocol_any_response_permutation_reassembles_by_id() {
    // The pipelined-client invariant: whatever order responses arrive in,
    // the Reassembler hands each request id back exactly its own reply.
    use dither::coordinator::{format_error, format_overloaded, format_response, Reassembler};
    check_with(
        Config {
            cases: 64,
            seed: 0x0DD5,
            max_shrink: 0,
        },
        &Pair(RangeUsize { lo: 1, hi: 64 }, RangeUsize { lo: 0, hi: 1 << 20 }),
        |&(n, seed)| {
            // Distinguishable payload per id: latency_us encodes the id.
            let make = |i: usize| -> (u64, String) {
                let id = 101 + i as u64;
                let line = match i % 3 {
                    0 => format_response(
                        id,
                        (i % 10) as u8,
                        SchemeId::ALL[i % SchemeId::COUNT],
                        4,
                        &[0.0; 10],
                        i as u64 * 7 + 1,
                        1,
                        0,
                        false,
                        false,
                    ),
                    1 => format_error(id, &format!("err-{i}"), i % 2 == 0),
                    _ => format_overloaded(id),
                };
                (id, line)
            };
            let mut lines: Vec<(u64, String)> = (0..n).map(make).collect();
            // Fisher–Yates with the case's seed: an arbitrary permutation.
            let mut rng = Xoshiro256pp::new(seed as u64);
            for i in (1..lines.len()).rev() {
                let j = rng.below((i + 1) as u64) as usize;
                lines.swap(i, j);
            }
            let mut reasm = Reassembler::new();
            for (_, line) in &lines {
                if reasm.insert(line).is_err() {
                    return false;
                }
            }
            if reasm.len() != n {
                return false;
            }
            for i in 0..n {
                let (id, original) = make(i);
                match reasm.take(id) {
                    Some(got) if got == original => {}
                    _ => return false,
                }
            }
            reasm.is_empty()
        },
    );
}

#[test]
fn prop_trace_wire_tag_roundtrip_and_malformed_downgrade() {
    // The proto-3 `"trace":"<id:flags>"` request field: encode → decode is
    // lossless for every id/flag combination, and anything malformed
    // decodes to None (downgrade to untraced, never reject or panic).
    use dither::trace::{decode_wire, encode_wire, FLAG_SAMPLED};
    struct TagGen;
    impl Gen for TagGen {
        type Item = (u64, u8);
        fn gen(&self, rng: &mut Xoshiro256pp) -> (u64, u8) {
            let flags = if rng.bernoulli(0.5) { FLAG_SAMPLED } else { 0 };
            (rng.below(u64::MAX), flags)
        }
    }
    check(&TagGen, |&(id, flags)| {
        decode_wire(&encode_wire(id, flags)) == Some((id, flags))
    });
    for bad in [
        "",
        ":",
        "0123:1",                // id not 16 hex digits
        "0123456789abcdeg:1",    // non-hex digit
        "0123456789abcdef",      // no flags separator
        "0123456789abcdef:",     // empty flags
        "0123456789abcdef:256",  // flags overflow u8
        "0123456789abcdef:1:1",  // trailing junk in flags
        " 0123456789abcdef:1",   // leading space
    ] {
        assert_eq!(decode_wire(bad), None, "{bad:?} must downgrade");
    }
}

#[test]
fn prop_trace_reply_roundtrip_through_format_and_parse() {
    // A committed Trace survives to_json → from_json exactly, and a full
    // `{"cmd":"trace"}` reply line (format_traces) parses back to the same
    // records (parse_traces) — the contract the proxy's cross-process
    // stitcher rests on.
    use dither::coordinator::{format_traces, parse_traces};
    use dither::trace::{Span, Stage, Trace};
    struct TraceGen;
    impl Gen for TraceGen {
        type Item = Vec<Trace>;
        fn gen(&self, rng: &mut Xoshiro256pp) -> Vec<Trace> {
            (0..rng.below(5))
                .map(|_| {
                    let spans = (0..rng.below(8))
                        .map(|i| Span {
                            stage: Stage::ALL[rng.below(Stage::COUNT as u64) as usize],
                            start_us: rng.below(1 << 40),
                            dur_us: rng.below(1 << 30),
                            note: rng.bernoulli(0.3).then(|| format!("wide/dither-{i}")),
                        })
                        .collect();
                    let model = ["digits_linear", "fashion_mlp", ""][rng.below(3) as usize];
                    let scheme = SchemeId::ALL[rng.below(SchemeId::COUNT as u64) as usize];
                    Trace {
                        trace_id: rng.below(u64::MAX),
                        request_id: rng.below(1 << 48),
                        model: model.to_string(),
                        scheme: scheme.wire_name().to_string(),
                        k: 1 + rng.below(16) as u32,
                        shard: rng.bernoulli(0.5).then(|| rng.below(16) as usize),
                        total_us: rng.below(1 << 40),
                        sampled: rng.bernoulli(0.8),
                        slow: rng.bernoulli(0.2),
                        spans,
                    }
                })
                .collect()
        }
    }
    check(&TraceGen, |traces| {
        traces
            .iter()
            .all(|t| Trace::from_json(&t.to_json()).as_ref() == Some(t))
            && parse_traces(&format_traces(traces)) == Ok(traces.clone())
    });
}

#[test]
fn prop_trace_query_roundtrip_through_parse_message() {
    // format_trace_query → parse_message preserves every filter — and the
    // zero query (all filters elided off the wire) parses to the default.
    use dither::coordinator::{format_trace_query, parse_message, Message, TraceQuery};
    struct QueryGen;
    impl Gen for QueryGen {
        type Item = TraceQuery;
        fn gen(&self, rng: &mut Xoshiro256pp) -> TraceQuery {
            TraceQuery {
                min_us: rng.below(1 << 32),
                model: rng.bernoulli(0.5).then(|| "digits_linear".to_string()),
                scheme: rng.bernoulli(0.5).then(|| {
                    SchemeId::ALL[rng.below(SchemeId::COUNT as u64) as usize]
                        .wire_name()
                        .to_string()
                }),
                limit: rng.below(1 << 16) as usize,
            }
        }
    }
    check(&QueryGen, |q| match parse_message(&format_trace_query(q)) {
        Ok(Message::Trace(parsed)) => parsed == *q,
        _ => false,
    });
}

#[test]
fn prop_metrics_reply_roundtrip_escapes_arbitrary_expositions() {
    // The `{"cmd":"metrics"}` reply carries a multi-line Prometheus text
    // body through the newline-delimited protocol via JSON string
    // escaping: any text — newlines, quotes, backslashes — survives the
    // wrap/unwrap exactly and never spills onto a second wire line.
    use dither::coordinator::{format_metrics_reply, parse_metrics_reply};
    struct TextGen;
    impl Gen for TextGen {
        type Item = String;
        fn gen(&self, rng: &mut Xoshiro256pp) -> String {
            let len = rng.below(400) as usize;
            (0..len)
                .map(|_| match rng.below(6) {
                    0 => '\n',
                    1 => '"',
                    2 => '\\',
                    3 => '{',
                    _ => (rng.below(95) as u8 + 32) as char,
                })
                .collect()
        }
    }
    check(&TextGen, |text| {
        let line = format_metrics_reply(text);
        !line.contains('\n') && parse_metrics_reply(&line) == Ok(text.clone())
    });
}

#[test]
fn prop_protocol_event_wire_roundtrip() {
    // A journal event survives to_json → from_json exactly, and a full
    // delivered watch line (format_event_line) parses back to the same
    // (subscription id, event) — the contract both the cluster stitcher
    // and every watch client rest on. Labels exercise JSON escaping.
    use dither::obs::{format_event_line, parse_event_line, Event, EventKind, Severity};
    use std::collections::BTreeMap;
    struct EventGen;
    impl Gen for EventGen {
        type Item = (u64, Event);
        fn gen(&self, rng: &mut Xoshiro256pp) -> (u64, Event) {
            let severities = [Severity::Info, Severity::Warn, Severity::Error];
            let mut labels = BTreeMap::new();
            for i in 0..rng.below(5) {
                let value: String = (0..rng.below(12))
                    .map(|_| match rng.below(8) {
                        0 => '"',
                        1 => '\\',
                        2 => '{',
                        _ => (rng.below(95) as u8 + 32) as char,
                    })
                    .collect();
                labels.insert(format!("label-{i}"), value);
            }
            let event = Event {
                seq: rng.below(1 << 48),
                t_us: rng.below(1 << 48),
                severity: severities[rng.below(3) as usize],
                kind: EventKind::ALL[rng.below(EventKind::ALL.len() as u64) as usize],
                labels,
            };
            (rng.below(1 << 32), event)
        }
    }
    check(&EventGen, |(sub, event)| {
        let line = format_event_line(*sub, event);
        !line.contains('\n')
            && Event::from_json(&event.to_json()).as_ref() == Some(event)
            && parse_event_line(&line) == Some((*sub, event.clone()))
    });
}

#[test]
fn prop_protocol_watch_verbs_roundtrip_through_parse_message() {
    // The v4 subscription verbs: format_watch → parse_message preserves
    // every filter combination (and the zero query parses back to the
    // default), format_unwatch carries its id, and both ack shapes echo
    // exactly what the server granted.
    use dither::coordinator::{
        format_unwatch, format_unwatch_ack, format_watch, format_watch_ack, parse_message,
        parse_watch_ack, Message, WatchQuery,
    };
    use dither::obs::{EventKind, Severity};
    struct WatchGen;
    impl Gen for WatchGen {
        type Item = (WatchQuery, u64, bool);
        fn gen(&self, rng: &mut Xoshiro256pp) -> (WatchQuery, u64, bool) {
            let severities = [Severity::Info, Severity::Warn, Severity::Error];
            let severity = rng
                .bernoulli(0.7)
                .then(|| severities[rng.below(3) as usize]);
            let kinds = EventKind::ALL
                .into_iter()
                .filter(|_| rng.bernoulli(0.3))
                .collect();
            (WatchQuery { severity, kinds }, rng.below(1 << 32), rng.bernoulli(0.5))
        }
    }
    check(&WatchGen, |(q, id, removed)| {
        let watch_ok = match parse_message(&format_watch(q)) {
            Ok(Message::Watch(parsed)) => parsed == *q,
            _ => false,
        };
        let unwatch_ok = matches!(
            parse_message(&format_unwatch(*id)),
            Ok(Message::Unwatch(got)) if got == *id
        );
        let ack_ok = parse_watch_ack(&format_watch_ack(*id)) == Ok(*id);
        let unack = Json::parse(&format_unwatch_ack(*id, *removed)).expect("unwatch ack json");
        let unack_ok = unack.get("unwatched").and_then(Json::as_f64) == Some(*id as f64)
            && unack.get("removed").and_then(Json::as_bool) == Some(*removed);
        watch_ok && unwatch_ok && ack_ok && unack_ok
    });
}

/// Generator for cluster hash-ring shapes: (member count, virtual nodes
/// per member).
fn ring_shape() -> Pair<RangeUsize, RangeUsize> {
    Pair(RangeUsize { lo: 2, hi: 8 }, RangeUsize { lo: 48, hi: 128 })
}

/// The routing keys every ring property drives: the shape the proxy
/// actually routes (model/config keys), plus numeric variety.
fn ring_keys() -> Vec<String> {
    (0..1000)
        .map(|i| format!("model-{}/scheme-{}/k={}", i % 5, i % 3, i))
        .collect()
}

#[test]
fn prop_ring_balances_keys_across_members_within_bound() {
    use dither::cluster::HashRing;
    check_with(
        Config {
            cases: 40,
            seed: 0x41AB,
            max_shrink: 0,
        },
        &ring_shape(),
        |&(members, replicas)| {
            let ring = HashRing::with_members(replicas, members);
            let mut counts = vec![0usize; members];
            let keys = ring_keys();
            for k in &keys {
                counts[ring.route(k).expect("non-empty ring routes")] += 1;
            }
            // Every member holds a real share: within [1/5x, 4x] of the
            // uniform share across 1k keys — virtual nodes are what keep
            // this tight.
            let uniform = keys.len() / members;
            counts.iter().all(|&c| c >= uniform / 5 && c <= uniform * 4)
        },
    );
}

#[test]
fn prop_ring_join_moves_only_keys_onto_the_new_member() {
    use dither::cluster::HashRing;
    check_with(
        Config {
            cases: 40,
            seed: 0x41AC,
            max_shrink: 0,
        },
        &ring_shape(),
        |&(members, replicas)| {
            let before = HashRing::with_members(replicas, members);
            let mut after = before.clone();
            after.add(members); // new member joins
            let keys = ring_keys();
            let mut moved = 0usize;
            for k in &keys {
                let a = before.route(k).unwrap();
                let b = after.route(k).unwrap();
                if a != b {
                    // Minimal remapping: a moved key may only land on the
                    // joiner — no key shuffles between old members.
                    if b != members {
                        return false;
                    }
                    moved += 1;
                }
            }
            // The joiner takes roughly its uniform share, nothing more.
            moved >= 1 && moved <= keys.len() * 4 / (members + 1)
        },
    );
}

#[test]
fn prop_ring_leave_keeps_every_other_members_keys() {
    use dither::cluster::HashRing;
    check_with(
        Config {
            cases: 40,
            seed: 0x41AD,
            max_shrink: 0,
        },
        &ring_shape(),
        |&(members, replicas)| {
            let before = HashRing::with_members(replicas, members);
            let leaver = members / 2;
            let mut after = before.clone();
            after.remove(leaver);
            ring_keys().iter().all(|k| {
                let a = before.route(k).unwrap();
                let b = after.route(k).unwrap();
                // Keys on surviving members stay put; the leaver's keys
                // must land on survivors.
                if a == leaver {
                    b != leaver
                } else {
                    a == b
                }
            })
        },
    );
}

#[test]
fn prop_ring_dead_member_reroutes_deterministically_and_reversibly() {
    use dither::cluster::HashRing;
    check_with(
        Config {
            cases: 40,
            seed: 0x41AE,
            max_shrink: 0,
        },
        &ring_shape(),
        |&(members, replicas)| {
            let ring = HashRing::with_members(replicas, members);
            let dead = members - 1;
            ring_keys().iter().all(|k| {
                let owner = ring.route(k).unwrap();
                let rerouted = ring.route_where(k, |m| m != dead).unwrap();
                if owner != dead {
                    // Another member's death never moves a live member's
                    // keys (this is what makes mark-down non-disruptive).
                    rerouted == owner
                } else {
                    // The dead member's keys fail over, always to the same
                    // survivor (mark-up reverses it exactly: route()).
                    rerouted != dead && Some(rerouted) == ring.route_where(k, |m| m != dead)
                }
            })
        },
    );
}

#[test]
fn prop_ring_empty_ring_is_an_error_not_a_panic() {
    use dither::cluster::HashRing;
    let mut ring = HashRing::new(64);
    assert_eq!(ring.route("any/key"), None);
    ring.add(0);
    assert_eq!(ring.route("any/key"), Some(0));
    ring.remove(0);
    assert!(ring.is_empty());
    assert_eq!(ring.route("any/key"), None, "drained ring routes nowhere");
    assert_eq!(ring.route_where("any/key", |_| true), None);
}

#[test]
fn prop_op_truth_consistent_with_estimates_in_expectation() {
    // Coarse statistical property over random (x, y): the trial-mean of
    // dither estimates approaches the op truth for all ops.
    let cases = Pair(UnitF64::unit(), UnitF64::unit());
    check_with(
        Config {
            cases: 12,
            seed: 0xFEED,
            max_shrink: 0,
        },
        &cases,
        |&(x, y)| {
            let n = 128;
            let trials = 300;
            Op::ALL.iter().all(|&op| {
                let mut rng = Xoshiro256pp::new(77);
                let mean: f64 = (0..trials)
                    .map(|_| op.estimate(Scheme::Dither, x, y, n, &mut rng))
                    .sum::<f64>()
                    / trials as f64;
                (mean - op.truth(x, y)).abs() < 0.02
            })
        },
    );
}
