//! Property-based tests (via the in-tree `propcheck` mini-framework) on the
//! encoding, rounding, linalg and coordinator invariants.

use dither::bitstream::{
    encode_x, encode_y, BitSeq, DitherEncoder, DitherParams, Op, Scheme,
};
use dither::linalg::{quant_matmul, Matrix, QuantMatmulConfig, Variant};
use dither::rounding::{Quantizer, RoundingMode, ScalarRounder};
use dither::util::json::Json;
use dither::util::propcheck::{check, check_with, Config, Gen, Pair, RangeUsize, UnitF64};
use dither::util::rng::Xoshiro256pp;

/// Generator for (value, sequence length).
fn value_and_len() -> Pair<UnitF64, RangeUsize> {
    Pair(UnitF64::unit(), RangeUsize { lo: 1, hi: 512 })
}

#[test]
fn prop_estimates_stay_in_unit_interval() {
    check(&value_and_len(), |&(x, n)| {
        let mut rng = Xoshiro256pp::new(x.to_bits() ^ n as u64);
        Scheme::ALL.iter().all(|&s| {
            let v = encode_x(s, x, n, &mut rng).value();
            (0.0..=1.0).contains(&v)
        })
    });
}

#[test]
fn prop_dither_params_invariants() {
    // For every (x, N): δ ∈ [0, min(1, 2/N)], E = x exactly, Var ≤ 2/N².
    check(&value_and_len(), |&(x, n)| {
        let p = DitherParams::of(x, n);
        let delta_ok = p.delta >= 0.0 && p.delta <= (2.0 / n as f64).min(1.0) + 1e-12;
        let exp_ok = (p.expectation(n) - x).abs() < 1e-9;
        let var_ok = p.variance(n) <= 2.0 / (n * n) as f64 + 1e-12;
        delta_ok && exp_ok && var_ok
    });
}

#[test]
fn prop_dither_error_bounded_by_one_pulse_plus_noise() {
    // Dither sample error: deterministic part within 1/N of x; stochastic
    // residue is Binomial(N, δ≤2/N)/N, so P(err > 10/N) is astronomically
    // small. Checked as a hard bound with slack.
    check(&value_and_len(), |&(x, n)| {
        let mut rng = Xoshiro256pp::new(2 ^ x.to_bits() ^ (n as u64) << 1);
        let enc = DitherEncoder::prefix();
        let v = enc.encode(x, n, &mut rng).value();
        (v - x).abs() <= 12.0 / n as f64 + 1e-9
    });
}

#[test]
fn prop_and_is_commutative_and_bounded() {
    check(
        &Pair(Pair(UnitF64::unit(), UnitF64::unit()), RangeUsize { lo: 1, hi: 256 }),
        |&((x, y), n)| {
            let mut rng = Xoshiro256pp::new(x.to_bits() ^ y.to_bits().rotate_left(17) ^ n as u64);
            let a = encode_x(Scheme::Dither, x, n, &mut rng);
            let b = encode_y(Scheme::Dither, y, n, &mut rng);
            let ab = a.and(&b);
            let ba = b.and(&a);
            // commutative, and Z_s ≤ min(X_s, Y_s) (AND can't create ones)
            ab == ba && ab.value() <= a.value().min(b.value()) + 1e-12
        },
    );
}

#[test]
fn prop_mux_value_between_operands() {
    // U_i selects per-pulse, so U_s ∈ [min(X_s,Y_s), max(X_s,Y_s)]… not in
    // general (mix of disjoint index sets), but it IS bounded by the
    // per-index envelope: U_s ≤ max over sequences' values + 1 pulse. We
    // check the always-true invariant: U_s ∈ [0,1] and the exact identity
    // U = W·X + (1-W)·Y per pulse.
    check(
        &Pair(Pair(UnitF64::unit(), UnitF64::unit()), RangeUsize { lo: 1, hi: 200 }),
        |&((x, y), n)| {
            let mut rng = Xoshiro256pp::new(4 ^ x.to_bits() ^ y.to_bits().rotate_left(23) ^ n as u64);
            let xs = encode_x(Scheme::Dither, x, n, &mut rng);
            let ys = encode_x(Scheme::Dither, y, n, &mut rng);
            let w = BitSeq::from_fn(n, |i| i % 2 == 0);
            let u = BitSeq::mux(&w, &xs, &ys);
            (0..n).all(|i| u.get(i) == if w.get(i) { xs.get(i) } else { ys.get(i) })
        },
    );
}

#[test]
fn prop_scalar_rounders_floor_or_ceil() {
    struct Alpha;
    impl Gen for Alpha {
        type Item = f64;
        fn gen(&self, rng: &mut Xoshiro256pp) -> f64 {
            rng.uniform(-100.0, 100.0)
        }
    }
    check(&Alpha, |&v| {
        RoundingMode::ALL.iter().all(|&m| {
            let mut r = ScalarRounder::new(m, 32, 5);
            let out = r.round(v);
            out == v.floor() as i64 || out == v.ceil() as i64
        })
    });
}

#[test]
fn prop_quantizer_roundtrip_within_step() {
    check(
        &Pair(UnitF64 { lo: -1.0, hi: 1.0 }, RangeUsize { lo: 1, hi: 12 }),
        |&(v, k)| {
            let q = Quantizer::new(k as u32, -1.0, 1.0);
            let deq = q.dequant(q.quantize_round(v));
            (deq - v).abs() <= q.step() / 2.0 + 1e-9
        },
    );
}

#[test]
fn prop_quant_matmul_error_bounded_by_step_budget() {
    // |Ĉ - C|_∞ per entry ≤ q·(step_a + step_b + step_a·step_b) for any
    // mode/variant (each factor moves by at most one quantization step).
    let dims = RangeUsize { lo: 1, hi: 12 };
    check_with(
        Config {
            cases: 40,
            seed: 0xC0DE,
            max_shrink: 50,
        },
        &Pair(Pair(dims, RangeUsize { lo: 1, hi: 12 }), RangeUsize { lo: 1, hi: 6 }),
        |&((p, q), kbits)| {
            let mut rng = Xoshiro256pp::new((p * 31 + q) as u64);
            let a = Matrix::random_uniform(p, q, 0.0, 1.0, &mut rng);
            let b = Matrix::random_uniform(q, p, 0.0, 1.0, &mut rng);
            let c = a.matmul(&b);
            let step = 1.0 / ((1u32 << kbits) - 1).max(1) as f64;
            let budget = q as f64 * (2.0 * step + step * step) + 1e-9;
            Variant::ALL.iter().all(|&variant| {
                RoundingMode::ALL.iter().all(|&mode| {
                    let cfg = QuantMatmulConfig::unit(kbits as u32, mode, variant, 1);
                    let c_hat = quant_matmul(&a, &b, &cfg);
                    c.sub(&c_hat).max_abs() <= budget
                })
            })
        },
    );
}

#[test]
fn prop_json_roundtrip_floats() {
    struct Floats;
    impl Gen for Floats {
        type Item = Vec<f64>;
        fn gen(&self, rng: &mut Xoshiro256pp) -> Vec<f64> {
            (0..rng.below(20)).map(|_| rng.uniform(-1e6, 1e6)).collect()
        }
    }
    check(&Floats, |xs| {
        let j = Json::nums(xs);
        let back = Json::parse(&j.to_string()).unwrap();
        let ys = back.as_f64_vec().unwrap();
        xs.iter().zip(&ys).all(|(a, b)| {
            (a - b).abs() <= a.abs().max(1.0) * 1e-12
        })
    });
}

/// The [`BitSeq`] contract: every bit at position >= len in the last word
/// is zero, so `count_ones` (a plain word-wise popcount) equals the
/// per-index count.
fn tail_invariant_holds(s: &BitSeq) -> bool {
    let n = s.len();
    let rem = n % 64;
    let tail_clean = if rem == 0 {
        true
    } else {
        s.words().last().map(|w| w & !((1u64 << rem) - 1) == 0).unwrap_or(true)
    };
    tail_clean
        && s.words().len() == n.div_ceil(64)
        && s.count_ones() == s.iter().filter(|&b| b).count() as u64
        && s.count_ones() <= n as u64
}

#[test]
fn prop_bitseq_ops_preserve_tail_invariant() {
    // Every constructor and word-parallel op must keep bits past `len`
    // zero — `ones` and `mux` write `u64::MAX` / `!w` patterns that would
    // leak into the tail without `mask_tail`.
    check(
        &Pair(RangeUsize { lo: 1, hi: 320 }, RangeUsize { lo: 0, hi: 1 << 20 }),
        |&(n, seed)| {
            let mut rng = Xoshiro256pp::new(seed as u64);
            let a = BitSeq::from_fn(n, |_| rng.bernoulli(0.5));
            let b = BitSeq::from_fn(n, |_| rng.bernoulli(0.3));
            let w = BitSeq::from_fn(n, |i| i % 3 == 0);
            tail_invariant_holds(&BitSeq::zeros(n))
                && tail_invariant_holds(&BitSeq::ones(n))
                && tail_invariant_holds(&a)
                && tail_invariant_holds(&a.and(&b))
                && tail_invariant_holds(&BitSeq::mux(&w, &a, &b))
                && tail_invariant_holds(&BitSeq::mux(&BitSeq::zeros(n), &a, &BitSeq::ones(n)))
        },
    );
}

#[test]
fn prop_bitseq_mask_tail_repairs_raw_word_writes() {
    // `words_mut` callers must restore the invariant with `mask_tail`; the
    // repaired sequence reads all-ones below `len` and nothing above.
    check(&RangeUsize { lo: 1, hi: 320 }, |&n| {
        let mut s = BitSeq::zeros(n);
        for w in s.words_mut() {
            *w = u64::MAX;
        }
        s.mask_tail();
        tail_invariant_holds(&s) && s.count_ones() == n as u64 && s.value() == 1.0
    });
}

/// Structured request-message fuzz case: each field independently valid or
/// invalid; `parse_message` must accept exactly the all-valid combinations.
#[derive(Debug, Clone)]
struct ReqCase {
    k: i64,
    scheme: usize,
    pixels: usize,
    with_pixels: bool,
}

const SCHEME_SPELLINGS: [&str; 8] = [
    "dither",
    "stochastic",
    "deterministic",
    "det",
    "sr",
    "traditional",
    "fuzzy",
    "",
];
const VALID_SCHEMES: usize = 6;

struct ReqGen;
impl Gen for ReqGen {
    type Item = ReqCase;
    fn gen(&self, rng: &mut Xoshiro256pp) -> ReqCase {
        ReqCase {
            k: rng.below(24) as i64 - 4,
            scheme: rng.below(SCHEME_SPELLINGS.len() as u64) as usize,
            pixels: if rng.bernoulli(0.5) {
                784
            } else {
                rng.below(1000) as usize
            },
            with_pixels: rng.bernoulli(0.9),
        }
    }
}

#[test]
fn prop_protocol_accepts_exactly_the_valid_requests() {
    check(&ReqGen, |case| {
        let scheme = SCHEME_SPELLINGS[case.scheme];
        let mut line = format!("{{\"id\":1,\"k\":{},\"scheme\":\"{}\"", case.k, scheme);
        if case.with_pixels {
            line.push_str(",\"pixels\":[");
            line.push_str(&vec!["0.5"; case.pixels].join(","));
            line.push(']');
        }
        line.push('}');
        let should_parse = (1..=16).contains(&case.k)
            && case.scheme < VALID_SCHEMES
            && case.with_pixels
            && case.pixels == 784;
        match dither::coordinator::parse_message(&line) {
            Ok(dither::coordinator::Message::Infer(req)) => {
                should_parse && req.k == case.k as u32 && req.pixels.len() == 784
            }
            Ok(_) => false,
            Err(_) => !should_parse,
        }
    });
}

#[test]
fn prop_protocol_parse_never_panics_on_fuzz() {
    struct Garbage;
    impl Gen for Garbage {
        type Item = String;
        fn gen(&self, rng: &mut Xoshiro256pp) -> String {
            let len = rng.below(200) as usize;
            (0..len)
                .map(|_| {
                    let c = rng.below(96) as u8 + 32;
                    c as char
                })
                .collect()
        }
    }
    check(&Garbage, |s| {
        // Must return Ok or Err, never panic.
        let _ = dither::coordinator::parse_message(s);
        true
    });
}

#[test]
fn prop_op_truth_consistent_with_estimates_in_expectation() {
    // Coarse statistical property over random (x, y): the trial-mean of
    // dither estimates approaches the op truth for all ops.
    let cases = Pair(UnitF64::unit(), UnitF64::unit());
    check_with(
        Config {
            cases: 12,
            seed: 0xFEED,
            max_shrink: 0,
        },
        &cases,
        |&(x, y)| {
            let n = 128;
            let trials = 300;
            Op::ALL.iter().all(|&op| {
                let mut rng = Xoshiro256pp::new(77);
                let mean: f64 = (0..trials)
                    .map(|_| op.estimate(Scheme::Dither, x, y, n, &mut rng))
                    .sum::<f64>()
                    / trials as f64;
                (mean - op.truth(x, y)).abs() < 0.02
            })
        },
    );
}
