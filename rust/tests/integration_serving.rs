//! Serving-path integration: PJRT runtime + engine + TCP server, end to
//! end over the real AOT artifacts. Skipped (with a notice) when
//! `artifacts/manifest.json` is missing — run `make artifacts` first.

use dither::coordinator::{serve, Engine, ServerConfig};
use dither::data::{Dataset, Task};
use dither::rounding::RoundingMode;
use dither::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

fn artifacts_present() -> bool {
    let ok = std::path::Path::new("artifacts/manifest.json").exists();
    if !ok {
        eprintln!("SKIP: artifacts/manifest.json missing — run `make artifacts`");
    }
    ok
}

#[test]
fn engine_agrees_with_native_path_at_high_k() {
    if !artifacts_present() {
        return;
    }
    let engine = Engine::new("artifacts", 1500, 7).expect("engine");
    let ds = Dataset::synthesize(Task::Digits, 32, 0x7357);
    let pixels: Vec<&[f64]> = (0..ds.len()).map(|i| ds.images.row(i)).collect();
    // k=8 dither ≈ float model predictions (bias+relu in both paths).
    let outputs = engine
        .infer_batch("digits_linear", 8, RoundingMode::Dither, &pixels)
        .expect("infer");
    assert_eq!(outputs.len(), 32);
    let correct = outputs
        .iter()
        .zip(&ds.labels)
        .filter(|(o, &l)| o.pred == l)
        .count();
    assert!(
        correct >= 24,
        "artifact-path accuracy {correct}/32 too low at k=8"
    );
    for out in &outputs {
        assert_eq!(out.logits.len(), 10);
        assert!(out.logits.iter().all(|v| v.is_finite()));
    }
}

#[test]
fn engine_mode_and_k_change_results() {
    if !artifacts_present() {
        return;
    }
    let engine = Engine::new("artifacts", 1500, 7).expect("engine");
    let ds = Dataset::synthesize(Task::Digits, 4, 0x7358);
    let pixels: Vec<&[f64]> = (0..ds.len()).map(|i| ds.images.row(i)).collect();
    let a = engine
        .infer_batch("digits_linear", 2, RoundingMode::Dither, &pixels)
        .unwrap();
    let b = engine
        .infer_batch("digits_linear", 2, RoundingMode::Dither, &pixels)
        .unwrap();
    // Seeds advance per batch: stochastic logits differ between calls.
    let same = a
        .iter()
        .zip(&b)
        .all(|(x, y)| x.logits == y.logits);
    assert!(!same, "dither logits should vary across batches (seed advances)");
    // Deterministic mode is stable.
    let c = engine
        .infer_batch("digits_linear", 2, RoundingMode::Deterministic, &pixels)
        .unwrap();
    let d = engine
        .infer_batch("digits_linear", 2, RoundingMode::Deterministic, &pixels)
        .unwrap();
    assert!(c.iter().zip(&d).all(|(x, y)| x.logits == y.logits));
}

#[test]
fn engine_splits_oversized_batches() {
    if !artifacts_present() {
        return;
    }
    let engine = Engine::new("artifacts", 1500, 7).expect("engine");
    // 300 > largest artifact batch (256): must split and still answer all.
    let ds = Dataset::synthesize(Task::Digits, 300, 0x7359);
    let pixels: Vec<&[f64]> = (0..ds.len()).map(|i| ds.images.row(i)).collect();
    let outputs = engine
        .infer_batch("digits_linear", 4, RoundingMode::Stochastic, &pixels)
        .expect("infer");
    assert_eq!(outputs.len(), 300);
}

#[test]
fn fashion_mlp_serves() {
    if !artifacts_present() {
        return;
    }
    let engine = Engine::new("artifacts", 1500, 7).expect("engine");
    let ds = Dataset::synthesize(Task::Fashion, 8, 0x735A);
    let pixels: Vec<&[f64]> = (0..ds.len()).map(|i| ds.images.row(i)).collect();
    let outputs = engine
        .infer_batch("fashion_mlp", 6, RoundingMode::Dither, &pixels)
        .expect("infer");
    assert_eq!(outputs.len(), 8);
    assert!(outputs.iter().all(|o| o.logits.iter().all(|v| v.is_finite())));
}

#[test]
fn tcp_server_end_to_end() {
    if !artifacts_present() {
        return;
    }
    let addr = "127.0.0.1:17979";
    let cfg = ServerConfig {
        addr: addr.to_string(),
        max_batch: 8,
        max_wait_us: 500,
        artifacts_dir: "artifacts".to_string(),
        train_n: 800,
        seed: 7,
    };
    let server = std::thread::spawn(move || serve(&cfg));

    // Wait for the listener + engine to come up (engine trains models).
    let mut stream = None;
    for _ in 0..600 {
        match TcpStream::connect(addr) {
            Ok(s) => {
                stream = Some(s);
                break;
            }
            Err(_) => std::thread::sleep(Duration::from_millis(100)),
        }
    }
    let stream = stream.expect("server did not come up");
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    let mut line = String::new();

    // Ping (also confirms the engine finished initializing).
    writeln!(writer, "{{\"cmd\":\"ping\"}}").unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("pong"), "{line}");

    // Inference round-trip.
    let ds = Dataset::synthesize(Task::Digits, 1, 0x7E57);
    let req = format!(
        "{{\"id\":5,\"model\":\"digits_linear\",\"k\":4,\"mode\":\"dither\",\"pixels\":{}}}",
        Json::nums(ds.images.row(0))
    );
    writeln!(writer, "{req}").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let resp = Json::parse(line.trim()).expect("response json");
    assert_eq!(resp.get("id").unwrap().as_f64(), Some(5.0));
    assert!(resp.get("pred").is_some(), "{line}");
    assert!(resp.get("error").is_none(), "{line}");

    // Malformed request → error, connection stays usable.
    writeln!(writer, "{{\"k\":4}}").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("error"), "{line}");

    // Stats.
    writeln!(writer, "{{\"cmd\":\"stats\"}}").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let stats = Json::parse(line.trim()).expect("stats json");
    assert!(stats.get("requests").unwrap().as_f64().unwrap() >= 1.0);

    // Shutdown.
    writeln!(writer, "{{\"cmd\":\"shutdown\"}}").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("stopping"), "{line}");
    server.join().unwrap().expect("server exits cleanly");
}
