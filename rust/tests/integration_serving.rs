//! Serving-path integration: model zoo + engine + sharded TCP server, end
//! to end. The native engines need no AOT artifacts, so these tests always
//! run (the zoo trains small models on first use and caches the weights
//! under `artifacts/weights/`).

use dither::coordinator::{
    format_request, format_request_auto, format_unwatch, format_watch, parse_watch_ack, serve,
    wait_ready, Engine, Reassembler, ServerConfig, WatchQuery,
};
use dither::data::{Dataset, Task};
use dither::obs::{parse_event_line, EventKind};
use dither::rounding::SchemeId;
use dither::train::Zoo;
use dither::util::json::Json;
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

const TRAIN_N: usize = 300;

#[test]
fn engine_serves_accurately_at_high_k() {
    let engine = Engine::new(TRAIN_N, 7);
    let ds = Dataset::synthesize(Task::Digits, 32, 0x7357);
    let pixels: Vec<&[f64]> = (0..ds.len()).map(|i| ds.images.row(i)).collect();
    // k=8 dither ≈ float model predictions.
    let outputs = engine
        .infer_batch("digits_linear", 8, SchemeId::Dither, &pixels)
        .expect("infer");
    assert_eq!(outputs.len(), 32);
    let correct = outputs
        .iter()
        .zip(&ds.labels)
        .filter(|(o, &l)| o.pred == l)
        .count();
    assert!(correct >= 16, "serving accuracy {correct}/32 too low at k=8");
    for out in &outputs {
        assert_eq!(out.logits.len(), 10);
        assert!(out.logits.iter().all(|v| v.is_finite()));
    }
}

#[test]
fn engine_mode_and_seed_change_results() {
    let engine = Engine::new(TRAIN_N, 7);
    let ds = Dataset::synthesize(Task::Digits, 4, 0x7358);
    let pixels: Vec<&[f64]> = (0..ds.len()).map(|i| ds.images.row(i)).collect();
    let a = engine
        .infer_batch("digits_linear", 2, SchemeId::Dither, &pixels)
        .unwrap();
    let b = engine
        .infer_batch("digits_linear", 2, SchemeId::Dither, &pixels)
        .unwrap();
    // Seeds advance per batch: dither logits differ between calls.
    let same = a.iter().zip(&b).all(|(x, y)| x.logits == y.logits);
    assert!(!same, "dither logits should vary across batches (seed advances)");
    // Deterministic mode is stable across calls, and across engines with
    // different seed streams (it never reads the seed).
    let zoo = std::sync::Arc::new(Zoo::load(TRAIN_N, 7));
    let e1 = Engine::from_zoo(zoo.clone(), 7);
    let e2 = Engine::from_zoo(zoo, 99);
    let c = e1
        .infer_batch("digits_linear", 2, SchemeId::Deterministic, &pixels)
        .unwrap();
    let d = e2
        .infer_batch("digits_linear", 2, SchemeId::Deterministic, &pixels)
        .unwrap();
    assert!(c.iter().zip(&d).all(|(x, y)| x.logits == y.logits));
}

#[test]
fn fashion_mlp_serves() {
    let engine = Engine::new(TRAIN_N, 7);
    let ds = Dataset::synthesize(Task::Fashion, 8, 0x735A);
    let pixels: Vec<&[f64]> = (0..ds.len()).map(|i| ds.images.row(i)).collect();
    let outputs = engine
        .infer_batch("fashion_mlp", 6, SchemeId::Dither, &pixels)
        .expect("infer");
    assert_eq!(outputs.len(), 8);
    assert!(outputs.iter().all(|o| o.logits.iter().all(|v| v.is_finite())));
}

fn connect_when_up(addr: &str) -> TcpStream {
    assert!(
        wait_ready(addr, Duration::from_secs(120)),
        "server did not come up on {addr}"
    );
    TcpStream::connect(addr).expect("connect after ready")
}

#[test]
fn tcp_server_end_to_end_sharded() {
    let addr = "127.0.0.1:17979";
    let cfg = ServerConfig {
        addr: addr.to_string(),
        shards: 4,
        max_batch: 8,
        max_wait_us: 500,
        queue_cap: 64,
        train_n: TRAIN_N,
        seed: 7,
        prewarm_bits: vec![4],
        shadow_rate: 1.0,
        plan_cache_mb: 64,
        max_inflight: 64,
        reply_timeout_ms: 120_000,
        // Trace everything: the verb checks below assert the full wave is
        // queryable from the ring.
        trace_rate: 1.0,
        trace_slow_us: 0,
        trace_buffer: 128,
        slo_p99_us: 0,
        slo_error_rate: 0.0,
        slo_mse_factor: 0.0,
        slo_eval_ms: 0,
    };
    let server = std::thread::spawn(move || serve(&cfg));

    // Wait until the server answers a ping (the zoo may be training).
    let stream = connect_when_up(addr);
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    let mut line = String::new();

    // Mixed-scheme inference round-trips on one connection — the paper's
    // trio plus the whole literature zoo; deterministic replies must match
    // a local reference engine exactly. (Same train_n and seed as the
    // server, so the reference model is identical even on a cold weight
    // cache.)
    let reference = Engine::new(TRAIN_N, 7);
    let ds = Dataset::synthesize(Task::Digits, 4, 0x7E57);
    let mut shard_seen = None;
    for (row, (id, mode)) in [
        (5u64, SchemeId::Dither),
        (6, SchemeId::Stochastic),
        (7, SchemeId::Deterministic),
        (40, SchemeId::Sr2),
        (41, SchemeId::SrVb),
        (42, SchemeId::Tpdf),
        (43, SchemeId::Gauss),
    ]
    .into_iter()
    .enumerate()
    {
        let pixels = ds.images.row(row % ds.len());
        writeln!(writer, "{}", format_request(id, "digits_linear", 4, mode, pixels)).unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        let resp = Json::parse(line.trim()).expect("response json");
        assert_eq!(resp.get("id").unwrap().as_f64(), Some(id as f64), "{line}");
        assert_eq!(resp.get("scheme").unwrap().as_str(), Some(mode.wire_name()), "{line}");
        assert!(resp.get("error").is_none(), "{line}");
        let shard = resp.get("shard").unwrap().as_f64().unwrap();
        match shard_seen {
            None => shard_seen = Some(shard),
            Some(s) => assert_eq!(s, shard, "connection must stay on one shard"),
        }
        if mode == SchemeId::Deterministic {
            let got = resp.get("logits").unwrap().as_f64_vec().unwrap();
            let want = reference
                .infer_batch("digits_linear", 4, mode, &[pixels])
                .unwrap();
            assert_eq!(got, want[0].logits, "deterministic logits must be exact");
        }
    }

    // Auto precision: the server resolves (scheme, k) from the error
    // budget and echoes its concrete choice tagged "auto". On a cold
    // estimator the controller works off the paper-shape prior, whose
    // cheapest candidate under a huge budget is deterministic k=1.
    writeln!(
        writer,
        "{}",
        format_request_auto(30, "digits_linear", 1e9, ds.images.row(0))
    )
    .unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let resp = Json::parse(line.trim()).expect("auto response json");
    assert!(resp.get("error").is_none(), "{line}");
    assert_eq!(resp.get("auto").unwrap().as_bool(), Some(true), "{line}");
    assert_eq!(resp.get("scheme").unwrap().as_str(), Some("deterministic"), "{line}");
    assert_eq!(resp.get("k").unwrap().as_f64(), Some(1.0), "{line}");

    // The legacy "mode" spelling still parses (hand-built on purpose —
    // format_request emits the current wire format).
    writeln!(
        writer,
        "{{\"id\":8,\"model\":\"digits_linear\",\"k\":4,\"mode\":\"dither\",\"pixels\":{}}}",
        Json::nums(ds.images.row(3))
    )
    .unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"pred\""), "{line}");

    // Malformed request → error, connection stays usable.
    writeln!(writer, "{{\"k\":4}}").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("error"), "{line}");

    // Unknown scheme → the unified error shape: the offending id echoed
    // and retryable:false (resending the same spelling can never succeed).
    writeln!(
        writer,
        "{{\"id\":9,\"model\":\"digits_linear\",\"k\":4,\"scheme\":\"sr9\",\"pixels\":{}}}",
        Json::nums(ds.images.row(1))
    )
    .unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let resp = Json::parse(line.trim()).expect("unknown-scheme error json");
    assert_eq!(resp.get("id").unwrap().as_f64(), Some(9.0), "{line}");
    assert!(resp.get("error").and_then(Json::as_str).is_some(), "{line}");
    assert_eq!(resp.get("retryable").unwrap().as_bool(), Some(false), "{line}");

    // A second connection lands on its own shard id deterministically and
    // still gets served.
    let stream2 = connect_when_up(addr);
    let mut reader2 = BufReader::new(stream2.try_clone().unwrap());
    let mut writer2 = stream2;
    writeln!(
        writer2,
        "{}",
        format_request(20, "fashion_mlp", 6, SchemeId::Dither, ds.images.row(0))
    )
    .unwrap();
    let mut line2 = String::new();
    reader2.read_line(&mut line2).unwrap();
    let resp2 = Json::parse(line2.trim()).expect("response json");
    assert!(resp2.get("error").is_none(), "{line2}");
    drop(writer2);
    drop(reader2);

    // Stats: merged counters across 4 shards.
    writeln!(writer, "{{\"cmd\":\"stats\"}}").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let stats = Json::parse(line.trim()).expect("stats json");
    assert!(stats.get("requests").unwrap().as_f64().unwrap() >= 5.0, "{line}");
    assert_eq!(stats.get("shards").unwrap().as_f64(), Some(4.0), "{line}");
    assert!(stats.get("errors").unwrap().as_f64().unwrap() >= 1.0, "{line}");
    assert!(
        stats.get("deprecated_fields").unwrap().as_f64().unwrap() >= 1.0,
        "the legacy \"mode\" spelling must be counted: {line}"
    );
    assert_eq!(
        stats
            .get("per_shard_requests")
            .unwrap()
            .as_f64_vec()
            .unwrap()
            .len(),
        4,
        "{line}"
    );
    // shadow_rate 1.0: every served request fed the fidelity estimators,
    // so the merged stats block reports per-(model, scheme, k) cells.
    let fidelity = stats.get("fidelity").expect("fidelity block").as_arr().unwrap();
    assert!(!fidelity.is_empty(), "{line}");
    let mut shadow_samples = 0.0;
    for entry in fidelity {
        for field in ["model", "scheme"] {
            assert!(entry.get(field).and_then(Json::as_str).is_some(), "{entry}");
        }
        for field in ["k", "samples", "bias", "mse", "variance"] {
            assert!(entry.get(field).and_then(Json::as_f64).is_some(), "{entry}");
        }
        shadow_samples += entry.get("samples").unwrap().as_f64().unwrap();
    }
    assert!(shadow_samples > 0.0, "{line}");

    // Windowed per-(model, k) cells ride in stats.recent alongside the
    // per-scheme cells (this connection served digits_linear at k=4).
    let recent = stats.get("recent").expect("recent section");
    assert!(recent.get("dither").is_some(), "{line}");
    let model_cell = recent.get("digits_linear/k=4").expect("per-(model,k) window cell");
    assert!(
        model_cell.get("requests").unwrap().as_f64().unwrap() >= 1.0,
        "{line}"
    );

    // Trace ring: at rate 1.0 every request above is queryable, each
    // with a full span timeline naming its serving stage breakdown.
    writeln!(writer, "{{\"cmd\":\"trace\",\"model\":\"digits_linear\"}}").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let traces = dither::coordinator::parse_traces(&line).expect("trace reply");
    assert!(
        traces.len() >= 5,
        "rate-1.0 sampling must retain the request wave: {line}"
    );
    for t in &traces {
        assert_eq!(t.model, "digits_linear");
        assert!(t.sampled);
        assert!(t.shard.is_some(), "server-side traces name their shard");
        let stages: Vec<&str> = t.spans.iter().map(|s| s.stage.name()).collect();
        for stage in ["parse", "admit", "queue", "assemble", "kernel", "serialize", "flush"] {
            assert!(stages.contains(&stage), "missing {stage} span: {stages:?}");
        }
        let kernel_span = t.spans.iter().find(|s| s.stage.name() == "kernel").unwrap();
        let note = kernel_span.note.as_deref().expect("kernel span notes kernel/scheme");
        assert!(note.ends_with(&format!("/{}", t.scheme)), "{note} vs {}", t.scheme);
    }
    // Filters compose: an impossible min_us returns nothing.
    writeln!(writer, "{{\"cmd\":\"trace\",\"min_us\":999999999}}").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"count\":0"), "{line}");

    // Metrics verb: a well-formed Prometheus exposition carrying the
    // same counters stats reports, plus tracer families.
    writeln!(writer, "{{\"cmd\":\"metrics\"}}").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let exposition = dither::coordinator::parse_metrics_reply(&line).expect("metrics reply");
    dither::trace::check_exposition(&exposition).expect("well-formed exposition");
    for family in [
        "dither_requests_total",
        "dither_latency_us_bucket",
        "dither_recent_latency_us_bucket",
        "dither_traces_committed_total",
        "dither_stage_duration_us_bucket",
    ] {
        assert!(exposition.contains(family), "missing {family}:\n{exposition}");
    }

    // Graceful shutdown: ack, then the server joins cleanly.
    writeln!(writer, "{{\"cmd\":\"shutdown\"}}").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("stopping"), "{line}");
    drop(writer);
    drop(reader);
    server.join().unwrap().expect("server exits cleanly");
}

#[test]
fn tcp_requests_pipeline_across_connections() {
    let addr = "127.0.0.1:17981";
    let cfg = ServerConfig {
        addr: addr.to_string(),
        shards: 2,
        max_batch: 16,
        max_wait_us: 2_000,
        queue_cap: 64,
        train_n: TRAIN_N,
        seed: 7,
        prewarm_bits: vec![4],
        shadow_rate: 0.0,
        plan_cache_mb: 64,
        max_inflight: 64,
        reply_timeout_ms: 120_000,
        trace_rate: 0.0,
        trace_slow_us: 0,
        trace_buffer: 256,
        slo_p99_us: 0,
        slo_error_rate: 0.0,
        slo_mse_factor: 0.0,
        slo_eval_ms: 0,
    };
    let server = std::thread::spawn(move || serve(&cfg));
    assert!(
        wait_ready(addr, Duration::from_secs(120)),
        "server did not come up on {addr}"
    );

    let ds = Dataset::synthesize(Task::Digits, 8, 0xC0C0);
    let clients: Vec<std::thread::JoinHandle<usize>> = (0..6)
        .map(|c| {
            let ds = ds.clone();
            std::thread::spawn(move || {
                let stream = TcpStream::connect(addr).expect("connect");
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let mut writer = stream;
                let mut ok = 0;
                let mut line = String::new();
                for j in 0..5u64 {
                    let id = (c * 10) as u64 + j;
                    let mode = SchemeId::PAPER[j as usize % 3];
                    let px = ds.images.row(((c as u64 + j) % 8) as usize);
                    writeln!(writer, "{}", format_request(id, "digits_linear", 4, mode, px))
                        .unwrap();
                    line.clear();
                    reader.read_line(&mut line).unwrap();
                    let resp = Json::parse(line.trim()).expect("json");
                    if resp.get("error").is_none()
                        && resp.get("id").and_then(Json::as_f64) == Some(id as f64)
                    {
                        ok += 1;
                    }
                }
                ok
            })
        })
        .collect();
    let total: usize = clients.into_iter().map(|h| h.join().unwrap()).sum();
    assert_eq!(total, 30, "all pipelined requests answered correctly");

    // Shut down.
    let stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    writeln!(writer, "{{\"cmd\":\"shutdown\"}}").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    server.join().unwrap().expect("server exits cleanly");
}

/// The W=32 mixed-scheme request grid the pipelined bit-identity test
/// drives: the paper's trio, two bit widths, eight distinct images.
fn mixed_cases(ds: &Dataset) -> Vec<(u64, SchemeId, u32, usize)> {
    (0..32)
        .map(|i| {
            let mode = SchemeId::PAPER[i % 3];
            let k = [2u32, 4][(i / 3) % 2];
            (i as u64 + 1, mode, k, i % ds.len())
        })
        .collect()
}

#[test]
fn pipelined_connection_one_reply_per_id_bit_identical_to_lockstep() {
    let addr = "127.0.0.1:17983";
    let cfg = ServerConfig {
        addr: addr.to_string(),
        shards: 2,
        max_batch: 16,
        max_wait_us: 1_000,
        queue_cap: 128,
        train_n: TRAIN_N,
        seed: 7,
        prewarm_bits: vec![2, 4],
        shadow_rate: 0.0,
        plan_cache_mb: 64,
        max_inflight: 32,
        reply_timeout_ms: 120_000,
        trace_rate: 0.0,
        trace_slow_us: 0,
        trace_buffer: 256,
        slo_p99_us: 0,
        slo_error_rate: 0.0,
        slo_mse_factor: 0.0,
        slo_eval_ms: 0,
    };
    let server = std::thread::spawn(move || serve(&cfg));
    let ds = Dataset::synthesize(Task::Digits, 8, 0xF1F0);
    let cases = mixed_cases(&ds);

    // Lockstep pass: its own connection, one request at a time — served
    // under the scalar kernel. The pipelined pass below switches the
    // process-global kernel to wide, so the deterministic bit-identity
    // assertion at the end doubles as a cross-kernel serving check.
    dither::kernels::select(dither::kernels::KernelId::Scalar);
    let stream = connect_when_up(addr);
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    let mut line = String::new();
    let mut lockstep_logits: HashMap<u64, Vec<f64>> = HashMap::new();
    for &(id, mode, k, row) in &cases {
        writeln!(
            writer,
            "{}",
            format_request(id, "digits_linear", k, mode, ds.images.row(row))
        )
        .unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        let resp = Json::parse(line.trim()).expect("lockstep response json");
        assert!(resp.get("error").is_none(), "{line}");
        assert_eq!(resp.get("id").unwrap().as_f64(), Some(id as f64), "{line}");
        lockstep_logits.insert(id, resp.get("logits").unwrap().as_f64_vec().unwrap());
    }

    // Pipelined pass: hello handshake, then all 32 requests before any
    // read, then reassemble the out-of-order replies by id — served under
    // the wide kernel (see above).
    dither::kernels::select(dither::kernels::KernelId::Wide);
    let stream2 = connect_when_up(addr);
    let mut reader2 = BufReader::new(stream2.try_clone().unwrap());
    let mut writer2 = stream2;
    writeln!(writer2, "{{\"cmd\":\"hello\"}}").unwrap();
    let mut line2 = String::new();
    reader2.read_line(&mut line2).unwrap();
    let hello = Json::parse(line2.trim()).expect("hello json");
    let features = hello.get("features").unwrap().as_arr().unwrap();
    assert!(
        features.iter().any(|f| f.as_str() == Some("pipelined")),
        "{line2}"
    );
    assert_eq!(hello.get("max_inflight").unwrap().as_f64(), Some(32.0), "{line2}");
    // Protocol v4: the watch/unwatch event-subscription verbs on top of
    // the v3 trace propagation and the v2 scheme zoo.
    assert_eq!(hello.get("proto").unwrap().as_f64(), Some(4.0), "{line2}");
    assert!(
        features.iter().any(|f| f.as_str() == Some("events")),
        "proto 4 must advertise the events feature: {line2}"
    );
    // The handshake names the process-global kernel selected above.
    assert_eq!(hello.get("kernel").unwrap().as_str(), Some("wide"), "{line2}");
    let advertised = hello.get("schemes").unwrap().as_arr().unwrap();
    for mode in SchemeId::ALL {
        assert!(
            advertised.iter().any(|s| s.as_str() == Some(mode.wire_name())),
            "hello must advertise {mode}: {line2}"
        );
    }

    for &(id, mode, k, row) in &cases {
        writeln!(
            writer2,
            "{}",
            format_request(id, "digits_linear", k, mode, ds.images.row(row))
        )
        .unwrap();
    }
    writer2.flush().unwrap();
    let mut reasm = Reassembler::new();
    for _ in 0..cases.len() {
        line2.clear();
        reader2.read_line(&mut line2).unwrap();
        reasm
            .insert(line2.trim())
            .expect("every reply carries a unique id");
    }
    assert_eq!(reasm.len(), cases.len());

    let mut shard_seen = None;
    for &(id, mode, k, row) in &cases {
        let reply = reasm.take(id).expect("exactly one reply per id");
        let resp = Json::parse(&reply).expect("pipelined response json");
        assert!(resp.get("error").is_none(), "{reply}");
        assert_eq!(resp.get("scheme").unwrap().as_str(), Some(mode.wire_name()), "{reply}");
        assert_eq!(resp.get("k").unwrap().as_f64(), Some(f64::from(k)), "{reply}");
        let shard = resp.get("shard").unwrap().as_f64().unwrap();
        match shard_seen {
            None => shard_seen = Some(shard),
            Some(s) => assert_eq!(s, shard, "pipelined connection must stay on one shard"),
        }
        if mode == SchemeId::Deterministic {
            // The acceptance bit-identity: deterministic rounding is
            // stateless per row, so lockstep (scalar kernel) and pipelined
            // (wide kernel) serving of the same (model, k, pixels) must
            // agree bit for bit no matter how the pipelined batches formed
            // and no matter which kernel computed them.
            let got = resp.get("logits").unwrap().as_f64_vec().unwrap();
            assert_eq!(
                got, lockstep_logits[&id],
                "deterministic reply for id {id} (k={k}, row {row}) diverged between \
                 lockstep/scalar and pipelined/wide serving"
            );
        }
    }
    assert!(reasm.is_empty());

    writeln!(writer2, "{{\"cmd\":\"shutdown\"}}").unwrap();
    line2.clear();
    reader2.read_line(&mut line2).unwrap();
    server.join().unwrap().expect("server exits cleanly");
    dither::kernels::select(dither::kernels::auto_detect());
}

#[test]
fn pipelined_shutdown_mid_stream_drains_accepted_ids() {
    let addr = "127.0.0.1:17984";
    let cfg = ServerConfig {
        addr: addr.to_string(),
        shards: 1,
        max_batch: 8,
        max_wait_us: 1_000,
        queue_cap: 64,
        train_n: TRAIN_N,
        seed: 7,
        prewarm_bits: vec![4],
        shadow_rate: 0.0,
        plan_cache_mb: 64,
        max_inflight: 64,
        reply_timeout_ms: 120_000,
        trace_rate: 0.0,
        trace_slow_us: 0,
        trace_buffer: 256,
        slo_p99_us: 0,
        slo_error_rate: 0.0,
        slo_mse_factor: 0.0,
        slo_eval_ms: 0,
    };
    let server = std::thread::spawn(move || serve(&cfg));
    let ds = Dataset::synthesize(Task::Digits, 8, 0xD0D0);

    let stream = connect_when_up(addr);
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    // Flood 16 requests and a shutdown in one burst, before reading
    // anything: the reader accepts all 16 (submission order), then the
    // shutdown stops intake — and the graceful drain must still answer
    // every accepted id before the connection closes.
    for id in 1..=16u64 {
        let px = ds.images.row(id as usize % 8);
        writeln!(
            writer,
            "{}",
            format_request(id, "digits_linear", 4, SchemeId::Dither, px)
        )
        .unwrap();
    }
    writeln!(writer, "{{\"cmd\":\"shutdown\"}}").unwrap();
    writer.flush().unwrap();

    let mut reasm = Reassembler::new();
    let mut stopping_acks = 0;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line).unwrap() == 0 {
            break; // server closed the connection after draining
        }
        if line.contains("stopping") {
            stopping_acks += 1;
            continue;
        }
        reasm.insert(line.trim()).expect("one reply per accepted id");
    }
    assert_eq!(stopping_acks, 1, "exactly one shutdown ack");
    assert_eq!(reasm.len(), 16, "every accepted id must be answered");
    for id in 1..=16u64 {
        let reply = reasm.take(id).expect("drained reply");
        let resp = Json::parse(&reply).expect("response json");
        assert!(
            resp.get("error").is_none(),
            "graceful drain must answer, not cancel: {reply}"
        );
        assert!(resp.get("pred").unwrap().as_f64().is_some(), "{reply}");
    }
    server.join().unwrap().expect("server exits cleanly");
}

#[test]
fn exceeding_inflight_window_is_overloaded_with_offending_id() {
    let addr = "127.0.0.1:17985";
    let cfg = ServerConfig {
        addr: addr.to_string(),
        shards: 1,
        max_batch: 32,
        // Long linger + distinct batch keys: responses trickle out one key
        // per linger period, so the client-side flood below outruns the
        // tiny window deterministically.
        max_wait_us: 150_000,
        queue_cap: 64,
        train_n: TRAIN_N,
        seed: 7,
        prewarm_bits: vec![],
        // Plan cache capped at 0 + full shadow rate: the unplanned A/B
        // baseline serves everything and must still populate
        // stats.fidelity (regression for the shadow_observe bugfix).
        shadow_rate: 1.0,
        plan_cache_mb: 0,
        max_inflight: 2,
        reply_timeout_ms: 120_000,
        trace_rate: 0.0,
        trace_slow_us: 0,
        trace_buffer: 256,
        slo_p99_us: 0,
        slo_error_rate: 0.0,
        slo_mse_factor: 0.0,
        slo_eval_ms: 0,
    };
    let server = std::thread::spawn(move || serve(&cfg));
    let ds = Dataset::synthesize(Task::Digits, 4, 0xBEEF);

    let stream = connect_when_up(addr);
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    let mut line = String::new();
    writeln!(writer, "{{\"cmd\":\"hello\"}}").unwrap();
    reader.read_line(&mut line).unwrap();
    let hello = Json::parse(line.trim()).expect("hello json");
    assert_eq!(hello.get("max_inflight").unwrap().as_f64(), Some(2.0), "{line}");

    // 8 requests with distinct keys (k = 1..=8) in one burst. The reader
    // accepts the first two; the rest exceed the window while the first
    // batch is still lingering and must be bounced with their own ids.
    for id in 1..=8u64 {
        writeln!(
            writer,
            "{}",
            format_request(id, "digits_linear", id as u32, SchemeId::Dither, ds.images.row(0))
        )
        .unwrap();
    }
    writer.flush().unwrap();

    let mut overloaded_ids = Vec::new();
    let mut served_ids = Vec::new();
    for _ in 0..8 {
        line.clear();
        reader.read_line(&mut line).unwrap();
        let resp = Json::parse(line.trim()).expect("response json");
        let id = resp.get("id").unwrap().as_f64().unwrap() as u64;
        if resp.get("overloaded").and_then(Json::as_bool).unwrap_or(false) {
            overloaded_ids.push(id);
        } else {
            assert!(resp.get("error").is_none(), "{line}");
            served_ids.push(id);
        }
    }
    overloaded_ids.sort_unstable();
    served_ids.sort_unstable();
    assert_eq!(served_ids, vec![1, 2], "the first two fill the window");
    assert_eq!(
        overloaded_ids,
        vec![3, 4, 5, 6, 7, 8],
        "requests beyond the window are bounced with their own ids"
    );

    // The window freed up once the accepted requests completed: a bounced
    // id retried now is accepted and served.
    writeln!(
        writer,
        "{}",
        format_request(3, "digits_linear", 3, SchemeId::Dither, ds.images.row(0))
    )
    .unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let resp = Json::parse(line.trim()).expect("retry response json");
    assert_eq!(resp.get("id").unwrap().as_f64(), Some(3.0), "{line}");
    assert!(resp.get("error").is_none(), "{line}");

    // stats.fidelity populated even though the plan cache is capped at 0
    // (the unplanned baseline path feeds the estimators).
    writeln!(writer, "{{\"cmd\":\"stats\"}}").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let stats = Json::parse(line.trim()).expect("stats json");
    let fidelity = stats.get("fidelity").expect("fidelity block").as_arr().unwrap();
    let samples: f64 = fidelity
        .iter()
        .filter_map(|e| e.get("samples").and_then(Json::as_f64))
        .sum();
    assert!(
        samples > 0.0,
        "plan cache capped at 0 must still feed fidelity estimators: {line}"
    );
    assert!(
        stats.get("rejected").unwrap().as_f64().unwrap() >= 6.0,
        "window rejections must be counted: {line}"
    );

    writeln!(writer, "{{\"cmd\":\"shutdown\"}}").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    server.join().unwrap().expect("server exits cleanly");
}

#[test]
fn control_verbs_bypass_the_inflight_window() {
    let addr = "127.0.0.1:17986";
    let cfg = ServerConfig {
        addr: addr.to_string(),
        shards: 1,
        max_batch: 32,
        // Long linger: the accepted request pins the lone window slot for
        // the whole exchange below.
        max_wait_us: 500_000,
        queue_cap: 64,
        train_n: TRAIN_N,
        seed: 7,
        prewarm_bits: vec![4],
        shadow_rate: 0.0,
        plan_cache_mb: 64,
        max_inflight: 1,
        reply_timeout_ms: 120_000,
        trace_rate: 0.0,
        trace_slow_us: 0,
        trace_buffer: 256,
        slo_p99_us: 0,
        slo_error_rate: 0.0,
        slo_mse_factor: 0.0,
        slo_eval_ms: 0,
    };
    let server = std::thread::spawn(move || serve(&cfg));
    let ds = Dataset::synthesize(Task::Digits, 4, 0xFACE);

    let stream = connect_when_up(addr);
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    let mut line = String::new();

    // One accepted request fills the window and lingers in its batch; a
    // second is bounced. Every control verb sent while the slot is pinned
    // must still be answered — none of them consume window slots.
    writeln!(
        writer,
        "{}",
        format_request(1, "digits_linear", 4, SchemeId::Dither, ds.images.row(0))
    )
    .unwrap();
    writeln!(
        writer,
        "{}",
        format_request(2, "digits_linear", 6, SchemeId::Dither, ds.images.row(1))
    )
    .unwrap();
    writeln!(writer, "{{\"cmd\":\"ping\"}}").unwrap();
    writeln!(writer, "{{\"cmd\":\"stats\"}}").unwrap();
    writeln!(writer, "{{\"cmd\":\"trace\"}}").unwrap();
    writeln!(writer, "{{\"cmd\":\"metrics\"}}").unwrap();
    writeln!(writer, "{}", format_watch(&WatchQuery::default())).unwrap();
    writer.flush().unwrap();

    // Replies land in submission order (the infer lingers past them all):
    // the bounce first, then each control ack.
    line.clear();
    reader.read_line(&mut line).unwrap();
    let bounce = Json::parse(line.trim()).expect("overloaded json");
    assert_eq!(bounce.get("id").unwrap().as_f64(), Some(2.0), "{line}");
    assert_eq!(bounce.get("overloaded").unwrap().as_bool(), Some(true), "{line}");
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("pong"), "ping at a full window: {line}");
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"requests\""), "stats at a full window: {line}");
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"count\""), "trace at a full window: {line}");
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("dither_requests_total"), "metrics at a full window: {line}");
    line.clear();
    reader.read_line(&mut line).unwrap();
    let watch_id = parse_watch_ack(line.trim()).expect("watch ack at a full window");
    writeln!(writer, "{}", format_unwatch(watch_id)).unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let ack = Json::parse(line.trim()).expect("unwatch ack json");
    assert_eq!(ack.get("unwatched").unwrap().as_f64(), Some(watch_id as f64), "{line}");
    assert_eq!(ack.get("removed").unwrap().as_bool(), Some(true), "{line}");

    // The pinned request itself still completes once its batch fires.
    line.clear();
    reader.read_line(&mut line).unwrap();
    let resp = Json::parse(line.trim()).expect("infer reply json");
    assert_eq!(resp.get("id").unwrap().as_f64(), Some(1.0), "{line}");
    assert!(resp.get("error").is_none(), "{line}");

    writeln!(writer, "{{\"cmd\":\"shutdown\"}}").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    server.join().unwrap().expect("server exits cleanly");
}

#[test]
fn slo_breach_fires_and_clears_through_a_watch() {
    let addr = "127.0.0.1:17987";
    let cfg = ServerConfig {
        addr: addr.to_string(),
        shards: 1,
        max_batch: 8,
        max_wait_us: 500,
        queue_cap: 64,
        train_n: TRAIN_N,
        seed: 7,
        prewarm_bits: vec![4],
        shadow_rate: 0.0,
        plan_cache_mb: 64,
        max_inflight: 64,
        reply_timeout_ms: 120_000,
        trace_rate: 0.0,
        trace_slow_us: 0,
        trace_buffer: 256,
        // A 1 µs latency budget: any served request breaches, so driving
        // traffic injects the SLO breach and stopping it clears the fast
        // window again.
        slo_p99_us: 1,
        slo_error_rate: 0.0,
        slo_mse_factor: 0.0,
        slo_eval_ms: 20,
    };
    let server = std::thread::spawn(move || serve(&cfg));
    let ds = Dataset::synthesize(Task::Digits, 4, 0x51_0);

    // Read one complete line from a timeout-armed socket. A timeout can
    // fire mid-line; partial data stays accumulated in `buf` across calls
    // and the buffer is only drained once a full line lands.
    fn poll_line(reader: &mut BufReader<TcpStream>, buf: &mut String) -> Option<String> {
        match reader.read_line(buf) {
            Ok(0) | Err(_) => None,
            Ok(_) => {
                let line = std::mem::take(buf);
                Some(line)
            }
        }
    }

    // Watcher connection, subscribed before any traffic.
    let watch_stream = connect_when_up(addr);
    watch_stream
        .set_read_timeout(Some(Duration::from_millis(100)))
        .unwrap();
    let mut watch_writer = watch_stream.try_clone().unwrap();
    let mut watch_reader = BufReader::new(watch_stream);
    let mut wline = String::new();
    writeln!(watch_writer, "{}", format_watch(&WatchQuery::default())).unwrap();
    let ack_deadline = std::time::Instant::now() + Duration::from_secs(30);
    let watch_id = loop {
        assert!(
            std::time::Instant::now() < ack_deadline,
            "watch ack never arrived"
        );
        if let Some(ack) = poll_line(&mut watch_reader, &mut wline) {
            break parse_watch_ack(ack.trim()).expect("watch ack");
        }
    };

    // Traffic connection: keep breaching until the alert streams out.
    let stream = connect_when_up(addr);
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    let mut line = String::new();
    let mut events = Vec::new();
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    let mut id = 0u64;
    while !events
        .iter()
        .any(|(_, e): &(u64, dither::obs::Event)| e.kind == EventKind::AlertFired)
    {
        assert!(
            std::time::Instant::now() < deadline,
            "latency alert never fired; events: {events:?}"
        );
        id += 1;
        writeln!(
            writer,
            "{}",
            format_request(id, "digits_linear", 4, SchemeId::Dither, ds.images.row(0))
        )
        .unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        if let Some(streamed) = poll_line(&mut watch_reader, &mut wline) {
            if let Some(parsed) = parse_event_line(&streamed) {
                assert_eq!(parsed.0, watch_id, "event tagged with the subscription id");
                events.push(parsed);
            }
        }
    }
    let fired = events
        .iter()
        .find(|(_, e)| e.kind == EventKind::AlertFired)
        .unwrap();
    assert_eq!(
        fired.1.labels.get("alert").map(String::as_str),
        Some("latency_p99"),
        "{:?}",
        fired.1
    );

    // While the alert is active, the exposition must carry the gauge and
    // the build-identity family.
    writeln!(writer, "{{\"cmd\":\"metrics\"}}").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let exposition = dither::coordinator::parse_metrics_reply(&line).expect("metrics reply");
    dither::trace::check_exposition(&exposition).expect("well-formed exposition");
    for family in ["dither_alert_active", "dither_build_info", "dither_events_total"] {
        assert!(exposition.contains(family), "missing {family}");
    }

    // Stop the traffic: the fast window drains and the alert clears.
    let clear_deadline = std::time::Instant::now() + Duration::from_secs(30);
    loop {
        if let Some(streamed) = poll_line(&mut watch_reader, &mut wline) {
            if let Some((_, e)) = parse_event_line(&streamed) {
                if e.kind == EventKind::AlertCleared {
                    break;
                }
            }
        }
        assert!(
            std::time::Instant::now() < clear_deadline,
            "latency alert never cleared after traffic stopped"
        );
    }

    writeln!(writer, "{{\"cmd\":\"shutdown\"}}").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    server.join().unwrap().expect("server exits cleanly");
}
