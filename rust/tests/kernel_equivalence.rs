//! Kernel-equivalence property suite: every registered kernel variant must
//! agree **bit for bit** with the scalar reference on every primitive, over
//! random shapes and lengths including ragged tails shorter than one word
//! and shorter than one lane group. This is the contract that makes the
//! process-global kernel switch invisible to deterministic serving.
//!
//! The primitives are exercised through `kernels::get(id)` — bypassing the
//! process-global selection — so the suite is immune to other tests
//! flipping the global concurrently. One final test drives the public
//! `BitSeq`/`Matrix` paths under each global selection to pin the dispatch
//! wiring itself.

use dither::bitstream::BitSeq;
use dither::kernels::{self, KernelId, Kernels};
use dither::linalg::Matrix;
use dither::util::rng::{counter_hash, Xoshiro256pp};

/// Deterministic random word buffer (tail masking is the caller's business
/// here — kernels operate on raw words).
fn random_words(len: usize, rng: &mut Xoshiro256pp) -> Vec<u64> {
    (0..len).map(|_| rng.next_u64()).collect()
}

fn random_f64s(len: usize, rng: &mut Xoshiro256pp) -> Vec<f64> {
    (0..len).map(|_| rng.uniform(-1.0, 1.0)).collect()
}

/// Word-slice lengths that cover empty input, sub-lane tails, exact lane
/// groups, and off-by-one straddles of the wide kernel's 4-word unroll.
const WORD_LENS: [usize; 9] = [0, 1, 2, 3, 4, 5, 7, 16, 129];

/// f64 lengths covering empty, sub-lane, exact-lane and ragged shapes for
/// both the 4-wide and 8-wide accumulator groupings.
const F64_LENS: [usize; 10] = [0, 1, 2, 3, 5, 7, 8, 9, 64, 101];

#[test]
fn word_primitives_match_scalar_bit_for_bit() {
    let scalar = kernels::get(KernelId::Scalar);
    let mut rng = Xoshiro256pp::new(0xBEEF);
    for &len in &WORD_LENS {
        for round in 0..4 {
            let a = random_words(len, &mut rng);
            let b = random_words(len, &mut rng);
            let w = random_words(len, &mut rng);
            let mut want_and = vec![0u64; len];
            let mut want_mux = vec![0u64; len];
            scalar.and_words(&a, &b, &mut want_and);
            scalar.mux_words(&w, &a, &b, &mut want_mux);
            let want_pop = scalar.popcount_words(&a);
            let want_and_pop = scalar.and_popcount(&a, &b);
            for id in KernelId::ALL {
                let kern = kernels::get(id);
                let mut got = vec![0u64; len];
                kern.and_words(&a, &b, &mut got);
                assert_eq!(got, want_and, "{id} and_words len={len} round={round}");
                kern.mux_words(&w, &a, &b, &mut got);
                assert_eq!(got, want_mux, "{id} mux_words len={len} round={round}");
                assert_eq!(
                    kern.popcount_words(&a),
                    want_pop,
                    "{id} popcount len={len} round={round}"
                );
                assert_eq!(
                    kern.and_popcount(&a, &b),
                    want_and_pop,
                    "{id} and_popcount len={len} round={round}"
                );
            }
        }
    }
}

#[test]
fn dot_and_matmul_row_match_scalar_bit_for_bit() {
    let scalar = kernels::get(KernelId::Scalar);
    let mut rng = Xoshiro256pp::new(0xD07);
    for &q in &F64_LENS {
        for &r in &[0usize, 1, 3, 8, 9, 17] {
            let arow = random_f64s(q, &mut rng);
            let bt = random_f64s(r * q, &mut rng);
            let mut want = vec![0.0f64; r];
            scalar.matmul_row(&arow, &bt, &mut want);
            let want_dot = if q <= bt.len() {
                scalar.dot(&arow, &bt[..q])
            } else {
                0.0
            };
            for id in KernelId::ALL {
                let kern = kernels::get(id);
                let mut got = vec![0.0f64; r];
                kern.matmul_row(&arow, &bt, &mut got);
                // Exact equality, not approx: the contract is strict
                // index-order accumulation per output cell.
                assert_eq!(
                    got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "{id} matmul_row q={q} r={r}"
                );
                if q <= bt.len() {
                    assert_eq!(
                        kern.dot(&arow, &bt[..q]).to_bits(),
                        want_dot.to_bits(),
                        "{id} dot q={q}"
                    );
                }
            }
        }
    }
}

#[test]
fn round_row_applies_per_element_counter_hash_identically() {
    // A rounding closure with a data-dependent result pins both the hash
    // argument (counter_hash(seed, j)) and the visit order per element.
    let mut rng = Xoshiro256pp::new(0x5EED);
    for &len in &F64_LENS {
        for seed in [0u64, 7, 0xFFFF_FFFF_FFFF_0001] {
            let base = random_f64s(len, &mut rng);
            let mut want = base.clone();
            for (j, v) in want.iter_mut().enumerate() {
                let u = counter_hash(seed, j as u64);
                *v = (*v * 8.0).floor() / 8.0 + (u >> 40) as f64 * 1e-9;
            }
            for id in KernelId::ALL {
                let mut got = base.clone();
                kernels::get(id).round_row(
                    &mut |v, u| (v * 8.0).floor() / 8.0 + (u >> 40) as f64 * 1e-9,
                    &mut got,
                    seed,
                );
                assert_eq!(
                    got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "{id} round_row len={len} seed={seed}"
                );
            }
        }
    }
}

#[test]
fn public_paths_are_invariant_under_the_global_kernel_switch() {
    // Drive the dispatching call sites themselves (BitSeq ops, Matrix
    // matmul) under each global selection; restore auto afterwards.
    let mut seq_results: Vec<(Vec<u64>, Vec<u64>, u64, u64)> = Vec::new();
    let mut mat_results: Vec<Vec<f64>> = Vec::new();
    for id in KernelId::ALL {
        kernels::select(id);
        let mut rng2 = Xoshiro256pp::new(0xACE);
        let n = 1000;
        let a = BitSeq::from_fn(n, |_| rng2.bernoulli(0.37));
        let b = BitSeq::from_fn(n, |_| rng2.bernoulli(0.81));
        let w = BitSeq::from_fn(n, |_| rng2.bernoulli(0.50));
        seq_results.push((
            a.and(&b).words().to_vec(),
            w.mux(&a, &b).words().to_vec(),
            a.count_ones(),
            a.and_count(&b),
        ));
        let p = Matrix::random_uniform(9, 13, -1.0, 1.0, &mut Xoshiro256pp::new(4));
        let q = Matrix::random_uniform(13, 7, -1.0, 1.0, &mut Xoshiro256pp::new(5));
        mat_results.push(p.matmul(&q).data().to_vec());
    }
    for r in &seq_results[1..] {
        assert_eq!(r, &seq_results[0], "BitSeq ops vary with the global kernel");
    }
    for m in &mat_results[1..] {
        assert_eq!(
            m.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            mat_results[0].iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "Matrix::matmul varies with the global kernel"
        );
    }
    kernels::select(kernels::auto_detect());
}
