//! Cluster front-tier integration: 2 backend `serve` processes (threads)
//! behind one consistent-hash `proxy`, driven end to end over TCP.
//!
//! Locks the acceptance criteria of the cluster subsystem: proxy-served
//! deterministic replies are bit-identical to direct-backend replies, a
//! backend kill mid-flood triggers health mark-down and deterministic
//! re-routing with no lost accepted ids on live backends, a restarted
//! backend is probed back up, and the proxy's `stats` merges backend
//! counters and `fidelity` blocks (sums match the per-backend scrapes).
//!
//! Observability rides the same topology: the proxy samples every request
//! (`trace_rate` 1.0) and propagates the context upstream, the backends
//! run adoption-only (local rate 0 — every backend ring entry descends
//! from a proxy trace id), and after the kill → mark-down → re-route
//! cycle the stitched `{"cmd":"trace"}` reply must name the backend that
//! actually served each timeline. Both tiers' `{"cmd":"metrics"}`
//! expositions must be well-formed Prometheus text.

use dither::cluster::{run_proxy, ProxyConfig};
use dither::coordinator::{
    format_request, format_request_auto, format_unwatch, format_watch, parse_metrics_reply,
    parse_watch_ack, serve, wait_ready, ServerConfig, WatchQuery,
};
use dither::data::{Dataset, Task};
use dither::obs::{parse_event_line, Event, EventKind};
use dither::rounding::SchemeId;
use dither::util::json::Json;
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

const TRAIN_N: usize = 300;
const BACKEND1: &str = "127.0.0.1:17990";
const BACKEND2: &str = "127.0.0.1:17991";
const PROXY: &str = "127.0.0.1:17992";

fn backend_cfg(addr: &str) -> ServerConfig {
    ServerConfig {
        addr: addr.to_string(),
        shards: 1,
        max_batch: 8,
        max_wait_us: 500,
        queue_cap: 64,
        train_n: TRAIN_N,
        seed: 7,
        prewarm_bits: vec![2, 4],
        // Full shadow rate so the merged stats.fidelity block is
        // guaranteed to be populated by a short wave.
        shadow_rate: 1.0,
        plan_cache_mb: 64,
        max_inflight: 64,
        reply_timeout_ms: 120_000,
        // Adoption-only tracing: the backends never self-sample (rate 0,
        // slow 0) but keep a ring, so every entry they hold was adopted
        // from a proxy-propagated `"trace"` tag — each backend ring id is
        // guaranteed to stitch back to a proxy timeline.
        trace_rate: 0.0,
        trace_slow_us: 0,
        trace_buffer: 512,
        // SLO alerting off by default; the alert-routing test overrides
        // these with an unmeetable budget via struct update syntax.
        slo_p99_us: 0,
        slo_error_rate: 0.0,
        slo_mse_factor: 0.0,
        slo_eval_ms: 0,
    }
}

/// One request case: (id, model, scheme, k, image row).
type Case = (u64, &'static str, SchemeId, u32, usize);

/// Every concrete `(model, scheme, k ∈ {2,4})` key twice — the paper's
/// trio plus the whole literature zoo, 56 requests over 28 routing keys,
/// which the deterministic ring spreads across both backends.
fn cases() -> Vec<Case> {
    let mut out = Vec::new();
    let mut id = 0u64;
    for model in ["digits_linear", "fashion_mlp"] {
        for mode in SchemeId::ALL {
            for k in [2u32, 4] {
                for _ in 0..2 {
                    id += 1;
                    out.push((id, model, mode, k, id as usize % 8));
                }
            }
        }
    }
    out
}

fn row<'a>(digits: &'a Dataset, fashion: &'a Dataset, case: &Case) -> &'a [f64] {
    if case.1 == "fashion_mlp" {
        fashion.images.row(case.4)
    } else {
        digits.images.row(case.4)
    }
}

/// A reply the client should simply resend. Every error reply carries the
/// unified `retryable` flag — overload backpressure (window full, queue
/// full, backend down or lost mid-kill) and the transient errors of a
/// backend draining out from under the proxy all say `true`; a reply
/// wrongly marked `false` surfaces as a hard wave failure instead of a
/// silent retry.
fn retryable(resp: &Json) -> bool {
    resp.get("retryable").and_then(Json::as_bool).unwrap_or(false)
}

/// Drive `cases` through one pipelined connection to `addr`: hello
/// handshake, flood every request, then drain replies out of order,
/// resending retryable ones. If `kill` names a backend, its shutdown is
/// issued right after the flood — the mid-flight kill the re-route cycle
/// must absorb. Panics on a duplicate reply id or a deadline overrun;
/// returns the final reply per id (exactly one each — no lost ids).
fn drive_cases(
    addr: &str,
    cases: &[Case],
    digits: &Dataset,
    fashion: &Dataset,
    kill: Option<&str>,
) -> HashMap<u64, Json> {
    let stream = TcpStream::connect(addr).expect("connect");
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(Duration::from_millis(250))).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    let mut line = String::new();
    writeln!(writer, "{{\"cmd\":\"hello\"}}").unwrap();
    loop {
        match reader.read_line(&mut line) {
            Ok(_) => break,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) => {}
            Err(e) => panic!("hello read failed: {e}"),
        }
    }
    let hello = Json::parse(line.trim()).expect("hello json");
    assert!(
        hello
            .get("features")
            .and_then(Json::as_arr)
            .is_some_and(|f| f.iter().any(|v| v.as_str() == Some("pipelined"))),
        "{line}"
    );
    // Protocol v4 (watch/unwatch event subscriptions on top of the v3
    // trace propagation) holds at both tiers: the backend advertises its
    // registry, the proxy the intersection across healthy backends —
    // same-build backends, so the full zoo either way.
    assert_eq!(hello.get("proto").and_then(Json::as_f64), Some(4.0), "{line}");
    assert!(
        hello
            .get("features")
            .and_then(Json::as_arr)
            .is_some_and(|f| f.iter().any(|v| v.as_str() == Some("events"))),
        "both tiers must advertise the events feature: {line}"
    );
    let advertised = hello.get("schemes").and_then(Json::as_arr).expect("schemes list");
    for mode in SchemeId::ALL {
        assert!(
            advertised.iter().any(|s| s.as_str() == Some(mode.wire_name())),
            "hello must advertise {mode}: {line}"
        );
    }

    let by_id: HashMap<u64, &Case> = cases.iter().map(|c| (c.0, c)).collect();
    let mut outstanding: Vec<u64> = Vec::new();
    for case in cases {
        let px = row(digits, fashion, case);
        writeln!(writer, "{}", format_request(case.0, case.1, case.3, case.2, px)).unwrap();
        outstanding.push(case.0);
    }
    writer.flush().unwrap();
    if let Some(victim) = kill {
        shutdown_server(victim);
    }

    let mut done: HashMap<u64, Json> = HashMap::new();
    let deadline = Instant::now() + Duration::from_secs(120);
    line.clear();
    while !outstanding.is_empty() {
        assert!(Instant::now() < deadline, "undrained ids: {outstanding:?}");
        match reader.read_line(&mut line) {
            Ok(0) => panic!("connection closed with ids outstanding: {outstanding:?}"),
            Ok(_) => {}
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                continue; // partial line survives the tick
            }
            Err(e) => panic!("read failed: {e}"),
        }
        let resp = Json::parse(line.trim()).expect("reply json");
        line.clear();
        let id = resp.get("id").and_then(Json::as_f64).expect("reply id") as u64;
        let pos = outstanding
            .iter()
            .position(|&o| o == id)
            .unwrap_or_else(|| panic!("unexpected or duplicate reply for id {id}: {resp}"));
        if retryable(&resp) {
            // Back off a beat (health may still be converging), resend
            // under the same id.
            std::thread::sleep(Duration::from_millis(50));
            let case = by_id[&id];
            let px = row(digits, fashion, case);
            writeln!(writer, "{}", format_request(case.0, case.1, case.3, case.2, px)).unwrap();
            writer.flush().unwrap();
            continue;
        }
        outstanding.swap_remove(pos);
        done.insert(id, resp);
    }
    done
}

/// Structural checks on one wave plus the bit-identity assertion: each
/// deterministic reply's logits must equal `reference` (keyed by id) —
/// replies served through the proxy vs a direct backend connection.
fn check_wave(
    done: &HashMap<u64, Json>,
    cases: &[Case],
    reference: Option<&HashMap<u64, Vec<f64>>>,
) {
    for case in cases {
        let resp = &done[&case.0];
        assert!(resp.get("error").is_none(), "{resp}");
        assert_eq!(resp.get("scheme").and_then(Json::as_str), Some(case.2.wire_name()), "{resp}");
        assert_eq!(resp.get("k").and_then(Json::as_f64), Some(f64::from(case.3)), "{resp}");
        let logits = resp.get("logits").and_then(Json::as_f64_vec).expect("logits");
        assert_eq!(logits.len(), 10, "{resp}");
        assert!(logits.iter().all(|v| v.is_finite()), "{resp}");
        if case.2 == SchemeId::Deterministic {
            if let Some(reference) = reference {
                assert_eq!(
                    logits, reference[&case.0],
                    "deterministic reply for id {} (model {}, k={}) must be \
                     bit-identical through the proxy",
                    case.0, case.1, case.3
                );
            }
        }
    }
}

fn det_logits(done: &HashMap<u64, Json>, cases: &[Case]) -> HashMap<u64, Vec<f64>> {
    cases
        .iter()
        .filter(|c| c.2 == SchemeId::Deterministic)
        .map(|c| (c.0, done[&c.0].get("logits").and_then(Json::as_f64_vec).unwrap()))
        .collect()
}

fn fetch_stats(addr: &str) -> Json {
    let stream = TcpStream::connect(addr).expect("connect for stats");
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    writeln!(writer, "{{\"cmd\":\"stats\"}}").unwrap();
    writer.flush().unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    Json::parse(line.trim()).expect("stats json")
}

/// One-shot request/reply over a fresh connection: send `cmd`, return the
/// raw reply line (the `trace` / `metrics` verbs both answer in one line).
fn query_line(addr: &str, cmd: &str) -> String {
    let stream = TcpStream::connect(addr).expect("connect for query");
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    writeln!(writer, "{cmd}").unwrap();
    writer.flush().unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    line
}

/// The stage names of a JSON timeline's spans, in recorded order.
fn stage_names(timeline: &Json) -> Vec<String> {
    timeline
        .get("spans")
        .and_then(Json::as_arr)
        .map(|spans| {
            spans
                .iter()
                .filter_map(|s| s.get("stage").and_then(Json::as_str).map(str::to_string))
                .collect()
        })
        .unwrap_or_default()
}

fn shutdown_server(addr: &str) {
    let stream = TcpStream::connect(addr).expect("connect for shutdown");
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    writeln!(writer, "{{\"cmd\":\"shutdown\"}}").unwrap();
    let mut line = String::new();
    let _ = reader.read_line(&mut line);
}

fn fidelity_samples(stats: &Json) -> f64 {
    stats
        .get("fidelity")
        .and_then(Json::as_arr)
        .map(|cells| {
            cells
                .iter()
                .filter_map(|c| c.get("samples").and_then(Json::as_f64))
                .sum()
        })
        .unwrap_or(0.0)
}

/// Poll the proxy's merged stats until `healthy` backends are reported
/// (or panic after 60 s).
fn wait_healthy(proxy: &str, n: f64) -> Json {
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        let stats = fetch_stats(proxy);
        let healthy = stats
            .get("proxy")
            .and_then(|p| p.get("healthy"))
            .and_then(Json::as_f64)
            .unwrap_or(-1.0);
        if healthy == n {
            return stats;
        }
        assert!(
            Instant::now() < deadline,
            "proxy never reported {n} healthy backends: {stats}"
        );
        std::thread::sleep(Duration::from_millis(100));
    }
}

#[test]
fn proxy_over_two_backends_routes_survives_kill_and_merges_stats() {
    let b1 = std::thread::spawn(|| serve(&backend_cfg(BACKEND1)));
    let b2 = std::thread::spawn(|| serve(&backend_cfg(BACKEND2)));
    assert!(wait_ready(BACKEND1, Duration::from_secs(120)), "backend 1 up");
    assert!(wait_ready(BACKEND2, Duration::from_secs(120)), "backend 2 up");

    let proxy_cfg = ProxyConfig {
        addr: PROXY.to_string(),
        backends: vec![BACKEND1.to_string(), BACKEND2.to_string()],
        replicas: 64,
        backend_inflight: 32,
        probe_interval_ms: 100,
        probe_timeout_ms: 1_500,
        max_backoff_ms: 400,
        // Sample everything: each proxied request must yield a stitched
        // cross-process timeline.
        trace_rate: 1.0,
        trace_slow_us: 0,
        trace_buffer: 2_048,
    };
    let proxy = std::thread::spawn(move || run_proxy(&proxy_cfg));
    // The proxy answers `pong` only once a backend is probed healthy.
    assert!(wait_ready(PROXY, Duration::from_secs(60)), "proxy up");

    let digits = Dataset::synthesize(Task::Digits, 8, 0xC1C1);
    let fashion = Dataset::synthesize(Task::Fashion, 8, 0xC1C2);
    let cases = cases();

    // Wave 1 — direct to backend 1: the bit-identity reference.
    let direct = drive_cases(BACKEND1, &cases, &digits, &fashion, None);
    check_wave(&direct, &cases, None);
    let reference = det_logits(&direct, &cases);

    // Wave 2 — through the proxy: every reply matched by id, every
    // deterministic reply bit-identical to the direct-backend wave (the
    // backends share train_n/seed, so either backend's weights agree).
    let via_proxy = drive_cases(PROXY, &cases, &digits, &fashion, None);
    check_wave(&via_proxy, &cases, Some(&reference));

    // Auto precision through the proxy: the backend resolves and tags it.
    {
        let stream = TcpStream::connect(PROXY).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = stream;
        writeln!(
            writer,
            "{}",
            format_request_auto(500, "digits_linear", 1e9, digits.images.row(0))
        )
        .unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = Json::parse(line.trim()).expect("auto reply json");
        assert_eq!(resp.get("id").and_then(Json::as_f64), Some(500.0), "{line}");
        assert_eq!(resp.get("auto").and_then(Json::as_bool), Some(true), "{line}");
        assert_eq!(
            resp.get("scheme").and_then(Json::as_str),
            Some("deterministic"),
            "{line}"
        );
        assert_eq!(resp.get("k").and_then(Json::as_f64), Some(1.0), "{line}");
    }

    // Merged stats: counters equal the sum of the backend scrapes, the
    // fidelity block is populated and sums match, both backends carried
    // forwarded traffic (the 12-key grid spans both ring owners).
    let merged = fetch_stats(PROXY);
    let s1 = fetch_stats(BACKEND1);
    let s2 = fetch_stats(BACKEND2);
    let sum = |field: &str| {
        s1.get(field).and_then(Json::as_f64).unwrap()
            + s2.get(field).and_then(Json::as_f64).unwrap()
    };
    assert_eq!(
        merged.get("requests").and_then(Json::as_f64),
        Some(sum("requests")),
        "{merged}"
    );
    assert_eq!(merged.get("shards").and_then(Json::as_f64), Some(2.0), "{merged}");
    assert_eq!(
        merged
            .get("per_shard_requests")
            .and_then(Json::as_f64_vec)
            .map(|v| v.len()),
        Some(2),
        "{merged}"
    );
    let merged_samples = fidelity_samples(&merged);
    assert!(merged_samples > 0.0, "merged fidelity must be populated: {merged}");
    assert_eq!(
        merged_samples,
        fidelity_samples(&s1) + fidelity_samples(&s2),
        "fidelity samples must merge exactly"
    );
    let forwarded = merged
        .get("proxy")
        .and_then(|p| p.get("forwarded"))
        .and_then(Json::as_f64_vec)
        .expect("per-backend forwarded counters");
    assert_eq!(forwarded.len(), 2);
    assert!(
        forwarded.iter().all(|&f| f > 0.0),
        "the mixed key grid must route traffic to both backends: {forwarded:?}"
    );

    // The proxy sums the backends' raw log2 latency histograms bucket-wise
    // and recomputes cluster-wide percentiles from the merged histogram
    // (not per-backend maxima).
    let bucket_sum = |s: &Json| {
        s.get("latency_buckets")
            .and_then(Json::as_f64_vec)
            .map(|v| v.iter().sum::<f64>())
            .expect("latency_buckets histogram")
    };
    assert!(bucket_sum(&merged) > 0.0, "{merged}");
    assert_eq!(bucket_sum(&merged), bucket_sum(&s1) + bucket_sum(&s2), "{merged}");
    let wire: Vec<u64> = merged
        .get("latency_buckets")
        .and_then(Json::as_f64_vec)
        .unwrap()
        .iter()
        .map(|&b| b as u64)
        .collect();
    assert_eq!(
        merged.get("p99_us").and_then(Json::as_f64),
        Some(dither::coordinator::percentile_from_buckets(&wire, 0.99)),
        "{merged}"
    );
    // Both backends are this build, so the merged kernel label is theirs.
    assert_eq!(
        merged.get("kernel").and_then(Json::as_str),
        s1.get("kernel").and_then(Json::as_str),
        "{merged}"
    );
    assert!(merged.get("kernel").and_then(Json::as_str).is_some(), "{merged}");

    // Wave 3 — kill backend 2 mid-flood: the proxy must mark it down,
    // re-route its keys to backend 1, and answer every id exactly once
    // (retryable bounces included — no lost accepted ids).
    let under_kill = drive_cases(PROXY, &cases, &digits, &fashion, Some(BACKEND2));
    check_wave(&under_kill, &cases, Some(&reference));
    b2.join().unwrap().expect("backend 2 exits cleanly");
    let down = wait_healthy(PROXY, 1.0);
    assert_eq!(down.get("shards").and_then(Json::as_f64), Some(1.0), "{down}");

    // Wave 4 — steady state on the survivor: all keys now serve from
    // backend 1, still bit-identical.
    let rerouted = drive_cases(PROXY, &cases, &digits, &fashion, None);
    check_wave(&rerouted, &cases, Some(&reference));

    // Stitched tracing across the kill: wave 4 ran survivor-only at full
    // proxy sampling, so the newest proxy timelines carry route/forward
    // spans and stitch to an upstream timeline recorded by the backend
    // that actually served them — backend 1, the only healthy one. The
    // backend runs adoption-only (local rate 0), so its ring must hold
    // exactly those propagated trace ids.
    {
        let line = query_line(PROXY, "{\"cmd\":\"trace\",\"limit\":16}");
        let reply = Json::parse(line.trim()).expect("stitched trace json");
        let traces = reply.get("traces").and_then(Json::as_arr).expect("traces array");
        assert!(!traces.is_empty(), "{line}");
        let direct = query_line(BACKEND1, "{\"cmd\":\"trace\"}");
        let direct = Json::parse(direct.trim()).expect("backend trace json");
        let backend_ids: Vec<&str> = direct
            .get("traces")
            .and_then(Json::as_arr)
            .expect("backend traces array")
            .iter()
            .filter_map(|t| t.get("trace_id").and_then(Json::as_str))
            .collect();
        let mut stitched = 0usize;
        for t in traces {
            assert!(stage_names(t).iter().any(|s| s == "route"), "{t}");
            let Some(upstream) = t.get("upstream").and_then(Json::as_arr) else {
                // A retryable bounce under the inflight cap commits a
                // proxy-side-only timeline — legitimate, just not stitched.
                continue;
            };
            let id = t.get("trace_id").and_then(Json::as_str).expect("trace id");
            assert!(
                stage_names(t).iter().any(|s| s == "upstream_wait"),
                "a stitched timeline must carry the proxy's upstream wait: {t}"
            );
            for up in upstream {
                assert_eq!(
                    up.get("backend").and_then(Json::as_str),
                    Some(BACKEND1),
                    "survivor-only wave must be served by backend 1: {up}"
                );
                assert_eq!(up.get("trace_id").and_then(Json::as_str), Some(id), "{up}");
                let up_stages = stage_names(up);
                for want in ["parse", "admit", "queue", "assemble", "kernel", "serialize"] {
                    assert!(up_stages.iter().any(|s| s == want), "missing {want} span: {up}");
                }
                assert!(
                    backend_ids.contains(&id),
                    "backend ring must hold adopted id {id}"
                );
                stitched += 1;
            }
        }
        assert!(stitched > 0, "no stitched cross-process timeline: {line}");
    }

    // Recovery: restart backend 2 on the same address; the health probe
    // marks it back up and its keys return home.
    let b2b = std::thread::spawn(|| serve(&backend_cfg(BACKEND2)));
    assert!(wait_ready(BACKEND2, Duration::from_secs(120)), "backend 2 back up");
    let up = wait_healthy(PROXY, 2.0);
    assert_eq!(up.get("shards").and_then(Json::as_f64), Some(2.0), "{up}");
    let recovered = drive_cases(PROXY, &cases, &digits, &fashion, None);
    check_wave(&recovered, &cases, Some(&reference));

    // Tracing survives the recovery: the restarted backend (fresh ring)
    // adopts propagated contexts again, so the newest stitched timelines
    // name only live backends — and at least one stitches.
    {
        let line = query_line(PROXY, "{\"cmd\":\"trace\",\"limit\":16}");
        let reply = Json::parse(line.trim()).expect("stitched trace json");
        let traces = reply.get("traces").and_then(Json::as_arr).expect("traces array");
        let mut stitched = 0usize;
        for t in traces {
            for up in t.get("upstream").and_then(Json::as_arr).into_iter().flatten() {
                let addr = up.get("backend").and_then(Json::as_str);
                assert!(
                    addr == Some(BACKEND1) || addr == Some(BACKEND2),
                    "stitched backend must be a live member: {up}"
                );
                stitched += 1;
            }
        }
        assert!(stitched > 0, "post-recovery wave must stitch: {line}");
    }

    // Both tiers serve a well-formed Prometheus exposition over the same
    // socket protocol; the proxy's carries its cluster-only families.
    {
        let line = query_line(PROXY, "{\"cmd\":\"metrics\"}");
        let text =
            dither::coordinator::parse_metrics_reply(line.trim()).expect("proxy metrics reply");
        dither::trace::check_exposition(&text).expect("well-formed proxy exposition");
        assert!(text.contains("dither_proxy_backends"), "{text}");
        assert!(text.contains("dither_traces_committed_total"), "{text}");
        assert!(text.contains("dither_requests_total"), "{text}");
        let line = query_line(BACKEND1, "{\"cmd\":\"metrics\"}");
        let text =
            dither::coordinator::parse_metrics_reply(line.trim()).expect("backend metrics reply");
        dither::trace::check_exposition(&text).expect("well-formed backend exposition");
        assert!(text.contains("dither_requests_total"), "{text}");
        assert!(text.contains("dither_stage_duration_us_bucket"), "{text}");
    }

    // Shutdown: proxy first (tears down its backend pools), then the
    // backends directly — proxy shutdown must not touch them.
    shutdown_server(PROXY);
    proxy.join().unwrap().expect("proxy exits cleanly");
    assert!(
        fetch_stats(BACKEND1).get("requests").is_some(),
        "backends must survive a proxy shutdown"
    );
    shutdown_server(BACKEND1);
    shutdown_server(BACKEND2);
    b1.join().unwrap().expect("backend 1 exits cleanly");
    b2b.join().unwrap().expect("backend 2 restart exits cleanly");
}

// ---------------------------------------------------------------------------
// Live ops plane: cluster-wide watch subscriptions and alert stitching.
// ---------------------------------------------------------------------------

/// Read one complete line from a timeout-armed socket. A read timeout can
/// fire mid-line; partial data accumulates in `buf` across calls and the
/// buffer is only drained once a full line lands.
fn poll_line(reader: &mut BufReader<TcpStream>, buf: &mut String) -> Option<String> {
    match reader.read_line(buf) {
        Ok(0) | Err(_) => None,
        Ok(_) => Some(std::mem::take(buf)),
    }
}

/// A live watch subscription: the socket, its pending-line buffer, and
/// the subscription id the server acked.
struct WatchConn {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    buf: String,
    id: u64,
}

/// Subscribe to everything `addr` journals (works against a backend and
/// the proxy alike — same verb either way) and wait for the ack.
fn open_watch(addr: &str) -> WatchConn {
    let stream = TcpStream::connect(addr).expect("connect for watch");
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(Duration::from_millis(100))).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    let mut buf = String::new();
    writeln!(writer, "{}", format_watch(&WatchQuery::default())).unwrap();
    let deadline = Instant::now() + Duration::from_secs(30);
    let id = loop {
        assert!(Instant::now() < deadline, "watch ack never arrived from {addr}");
        if let Some(ack) = poll_line(&mut reader, &mut buf) {
            break parse_watch_ack(ack.trim()).expect("watch ack");
        }
    };
    WatchConn { reader, writer, buf, id }
}

impl WatchConn {
    /// One non-blocking-ish poll: a parsed event if a full line landed.
    fn poll_event(&mut self) -> Option<Event> {
        let line = poll_line(&mut self.reader, &mut self.buf)?;
        let (sub, event) = parse_event_line(line.trim())?;
        assert_eq!(sub, self.id, "event tagged with the subscription id: {line}");
        Some(event)
    }

    /// Collect streamed events until `pred` matches one (the match is the
    /// last element of the returned vec) or the deadline panics.
    fn wait_for(&mut self, what: &str, secs: u64, mut pred: impl FnMut(&Event) -> bool) -> Vec<Event> {
        let deadline = Instant::now() + Duration::from_secs(secs);
        let mut seen = Vec::new();
        loop {
            if let Some(event) = self.poll_event() {
                let hit = pred(&event);
                seen.push(event);
                if hit {
                    return seen;
                }
            }
            assert!(Instant::now() < deadline, "{what}; events so far: {seen:?}");
        }
    }

    /// Tear the subscription down and wait for the `unwatched` ack
    /// (skipping any event lines still in flight).
    fn unwatch(mut self) {
        writeln!(self.writer, "{}", format_unwatch(self.id)).unwrap();
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            assert!(Instant::now() < deadline, "unwatch ack never arrived");
            if let Some(line) = poll_line(&mut self.reader, &mut self.buf) {
                if line.contains("\"unwatched\"") {
                    assert!(line.contains("\"removed\":true"), "{line}");
                    return;
                }
            }
        }
    }
}

const BACKEND3: &str = "127.0.0.1:17993";
const BACKEND4: &str = "127.0.0.1:17994";
const PROXY2: &str = "127.0.0.1:17995";

#[test]
fn cluster_watch_survives_backend_kill_and_recovery() {
    let b1 = std::thread::spawn(|| serve(&backend_cfg(BACKEND3)));
    let b2 = std::thread::spawn(|| serve(&backend_cfg(BACKEND4)));
    assert!(wait_ready(BACKEND3, Duration::from_secs(120)), "backend 3 up");
    assert!(wait_ready(BACKEND4, Duration::from_secs(120)), "backend 4 up");
    let proxy_cfg = ProxyConfig {
        addr: PROXY2.to_string(),
        backends: vec![BACKEND3.to_string(), BACKEND4.to_string()],
        replicas: 64,
        backend_inflight: 32,
        probe_interval_ms: 100,
        probe_timeout_ms: 1_500,
        max_backoff_ms: 400,
        trace_rate: 0.0,
        trace_slow_us: 0,
        trace_buffer: 256,
    };
    let proxy = std::thread::spawn(move || run_proxy(&proxy_cfg));
    assert!(wait_ready(PROXY2, Duration::from_secs(60)), "proxy up");
    wait_healthy(PROXY2, 2.0);

    // One cluster-wide subscription watches the whole kill → mark-down →
    // recovery cycle.
    let mut watch = open_watch(PROXY2);

    shutdown_server(BACKEND4);
    b2.join().unwrap().expect("backend 4 exits cleanly");
    let down_events = watch.wait_for("no BackendDown for the killed backend", 60, |e| {
        e.kind == EventKind::BackendDown
            && e.labels.get("addr").map(String::as_str) == Some(BACKEND4)
    });

    let b2b = std::thread::spawn(|| serve(&backend_cfg(BACKEND4)));
    assert!(wait_ready(BACKEND4, Duration::from_secs(120)), "backend 4 back up");
    let up_events = watch.wait_for("no BackendUp after the recovery", 60, |e| {
        e.kind == EventKind::BackendUp
            && e.labels.get("addr").map(String::as_str) == Some(BACKEND4)
    });

    // The uninterrupted subscription saw the whole cycle in order:
    // journal seqs strictly increase across the stitcher's re-subscribe,
    // which also rules out duplicated events.
    let seqs: Vec<u64> = down_events.iter().chain(up_events.iter()).map(|e| e.seq).collect();
    assert!(
        seqs.windows(2).all(|w| w[0] < w[1]),
        "event seqs must strictly increase (ordered, duplicate-free): {seqs:?}"
    );

    // The cluster surface exposes the watch-plane counters: shed-line
    // drops and the live subscriber gauge (exactly our one watch).
    let line = query_line(PROXY2, "{\"cmd\":\"metrics\"}");
    let text = parse_metrics_reply(line.trim()).expect("proxy metrics reply");
    dither::trace::check_exposition(&text).expect("well-formed proxy exposition");
    assert!(text.contains("dither_events_dropped_total"), "{text}");
    assert!(text.contains("dither_watch_subscribers 1"), "{text}");
    assert!(text.contains("dither_events_total"), "{text}");

    watch.unwatch();
    shutdown_server(PROXY2);
    proxy.join().unwrap().expect("proxy exits cleanly");
    shutdown_server(BACKEND3);
    shutdown_server(BACKEND4);
    b1.join().unwrap().expect("backend 3 exits cleanly");
    b2b.join().unwrap().expect("backend 4 restart exits cleanly");
}

const BACKEND5: &str = "127.0.0.1:17996";
const BACKEND6: &str = "127.0.0.1:17997";
const PROXY3: &str = "127.0.0.1:17998";

#[test]
fn slo_breach_alert_reaches_direct_and_cluster_watches_then_clears() {
    // A 1 µs latency budget: every served request breaches, so traffic
    // injects the SLO breach and stopping it clears the fast window.
    let slo_cfg = |addr: &str| ServerConfig {
        slo_p99_us: 1,
        slo_eval_ms: 25,
        shadow_rate: 0.0,
        ..backend_cfg(addr)
    };
    let cfg5 = slo_cfg(BACKEND5);
    let cfg6 = slo_cfg(BACKEND6);
    let b1 = std::thread::spawn(move || serve(&cfg5));
    let b2 = std::thread::spawn(move || serve(&cfg6));
    assert!(wait_ready(BACKEND5, Duration::from_secs(120)), "backend 5 up");
    assert!(wait_ready(BACKEND6, Duration::from_secs(120)), "backend 6 up");
    let proxy_cfg = ProxyConfig {
        addr: PROXY3.to_string(),
        backends: vec![BACKEND5.to_string(), BACKEND6.to_string()],
        replicas: 64,
        backend_inflight: 32,
        probe_interval_ms: 100,
        probe_timeout_ms: 1_500,
        max_backoff_ms: 400,
        trace_rate: 0.0,
        trace_slow_us: 0,
        trace_buffer: 256,
    };
    let proxy = std::thread::spawn(move || run_proxy(&proxy_cfg));
    assert!(wait_ready(PROXY3, Duration::from_secs(60)), "proxy up");
    wait_healthy(PROXY3, 2.0);

    // Both vantage points subscribe before any traffic: one watch direct
    // on the breaching backend, one cluster-wide on the proxy.
    let mut direct_watch = open_watch(BACKEND5);
    let mut cluster_watch = open_watch(PROXY3);

    // Breach: serial traffic straight at backend 5 until its own watch
    // streams the burn-rate alert.
    let digits = Dataset::synthesize(Task::Digits, 4, 0xD17E);
    let stream = TcpStream::connect(BACKEND5).expect("traffic connect");
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    let mut line = String::new();
    let deadline = Instant::now() + Duration::from_secs(60);
    let mut id = 0u64;
    let mut fired = Vec::new();
    while fired.is_empty() {
        assert!(Instant::now() < deadline, "backend latency alert never fired");
        id += 1;
        writeln!(
            writer,
            "{}",
            format_request(id, "digits_linear", 4, SchemeId::Dither, digits.images.row(0))
        )
        .unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        if let Some(event) = direct_watch.poll_event() {
            if event.kind == EventKind::AlertFired {
                fired.push(event);
            }
        }
    }
    assert_eq!(
        fired[0].labels.get("alert").map(String::as_str),
        Some("latency_p99"),
        "{:?}",
        fired[0]
    );

    // The same breach must reach the cluster watch as a proxy-journal
    // alert transition stitched from the backend stream, tagged with the
    // originating backend id.
    let stitched = cluster_watch.wait_for("stitched AlertFired never reached the proxy", 60, |e| {
        e.kind == EventKind::AlertFired
            && e.labels.get("alert").map(String::as_str) == Some("latency_p99")
    });
    assert!(
        stitched.last().unwrap().labels.contains_key("backend"),
        "stitched alert must carry the backend tag: {:?}",
        stitched.last().unwrap()
    );

    // Clear: stop the traffic; the fast window drains on the backend and
    // the clear propagates to both watches.
    direct_watch.wait_for("backend latency alert never cleared", 60, |e| {
        e.kind == EventKind::AlertCleared
            && e.labels.get("alert").map(String::as_str) == Some("latency_p99")
    });
    cluster_watch.wait_for("stitched AlertCleared never reached the proxy", 60, |e| {
        e.kind == EventKind::AlertCleared
            && e.labels.get("alert").map(String::as_str) == Some("latency_p99")
    });

    direct_watch.unwatch();
    cluster_watch.unwatch();
    shutdown_server(PROXY3);
    proxy.join().unwrap().expect("proxy exits cleanly");
    shutdown_server(BACKEND5);
    shutdown_server(BACKEND6);
    b1.join().unwrap().expect("backend 5 exits cleanly");
    b2.join().unwrap().expect("backend 6 exits cleanly");
}
