//! Cross-module integration tests over the bitstream stack: the paper's
//! §II–§V claims at medium scale (larger than unit tests, smaller than the
//! CLI experiments).

use dither::bitstream::{
    evaluate, theory_deterministic_repr_emse, theory_stochastic_repr_emse, EvalConfig, Op,
    Scheme,
};
use dither::util::stats::loglog_slope;

fn cfg() -> EvalConfig {
    EvalConfig {
        pairs: 80,
        trials: 150,
        seed: 0x17E5,
    }
}

#[test]
fn table1_full_grid_orders() {
    // Empirical EMSE slopes across ALL (op, scheme) cells match Table I.
    let cfg = cfg();
    let pairs = cfg.draw_pairs();
    let ns = [16usize, 64, 256];
    let xs: Vec<f64> = ns.iter().map(|&n| n as f64).collect();
    for op in Op::ALL {
        for scheme in Scheme::ALL {
            let emse: Vec<f64> = ns
                .iter()
                .map(|&n| evaluate(scheme, op, n, &pairs, &cfg).emse)
                .collect();
            let slope = loglog_slope(&xs, &emse).unwrap();
            let expected = match scheme {
                Scheme::Stochastic => -1.0,
                _ => -2.0,
            };
            assert!(
                (slope - expected).abs() < 0.5,
                "{op:?}/{scheme:?}: EMSE slope {slope} (expected ~{expected}); series {emse:?}"
            );
        }
    }
}

#[test]
fn repr_emse_matches_closed_forms() {
    // §II-A: L = 1/(6N) for stochastic; §II-B: L = 1/(12N²) deterministic.
    let cfg = cfg();
    let pairs = cfg.draw_pairs();
    for &n in &[32usize, 128, 512] {
        let sto = evaluate(Scheme::Stochastic, Op::Represent, n, &pairs, &cfg).emse;
        let det = evaluate(Scheme::DeterministicVariant, Op::Represent, n, &pairs, &cfg).emse;
        let sto_th = theory_stochastic_repr_emse(n);
        let det_th = theory_deterministic_repr_emse(n);
        assert!(
            (sto - sto_th).abs() < 0.3 * sto_th,
            "N={n} stochastic: {sto} vs theory {sto_th}"
        );
        assert!(
            (det - det_th).abs() < 0.4 * det_th,
            "N={n} deterministic: {det} vs theory {det_th}"
        );
    }
}

#[test]
fn dither_emse_between_bound_and_zero_with_zero_bias() {
    let cfg = cfg();
    let pairs = cfg.draw_pairs();
    for &n in &[32usize, 128, 512] {
        let d = evaluate(Scheme::Dither, Op::Represent, n, &pairs, &cfg).emse;
        let bound = 2.0 / (n * n) as f64;
        assert!(d <= 1.2 * bound, "N={n}: dither EMSE {d} exceeds bound {bound}");
        // §II lower bound for any N-pulse scheme: 1/(12N²).
        let lower = 1.0 / (12.0 * (n * n) as f64);
        assert!(d >= 0.5 * lower, "N={n}: dither EMSE {d} below plausibility");
    }
}

#[test]
fn dither_mult_and_avg_same_order_as_deterministic_variant() {
    // §V claims dither's mult/avg EMSE beats the deterministic variant's.
    // Both are Θ(1/N²); the *constant* ordering depends on implementation
    // details the paper does not specify (see EXPERIMENTS.md §Deviations:
    // our clock-division baseline is tighter than the paper's 2/N bound,
    // and §IV-C's W-flip contributes irreducible O(1/N²) variance). What
    // must hold in any faithful implementation — and what we assert — is:
    //   (a) dither stays within a small constant of the deterministic
    //       variant (same 1/N² order, constant ≤ 1.5× mult / ≤ 4× avg),
    //   (b) dither is unbiased while the deterministic variant is not.
    let cfg = cfg();
    let pairs = cfg.draw_pairs();
    let n = 128;
    for (op, factor) in [(Op::Multiply, 1.5), (Op::Average, 4.0)] {
        let dit = evaluate(Scheme::Dither, op, n, &pairs, &cfg);
        let det = evaluate(Scheme::DeterministicVariant, op, n, &pairs, &cfg);
        assert!(
            dit.emse < det.emse * factor,
            "{op:?} at N={n}: dither EMSE {} should be within {factor}x of deterministic {}",
            dit.emse,
            det.emse
        );
        assert!(
            dit.bias_abs < det.bias_abs / 2.0,
            "{op:?} at N={n}: dither |bias| {} ≪ deterministic {}",
            dit.bias_abs,
            det.bias_abs
        );
    }
}

#[test]
fn sample_bias_ordering_and_sem_slopes() {
    // Figs 2/4/6: |bias| lower for the unbiased schemes than the
    // deterministic variant; dither's sample bias falls faster than
    // stochastic's (SEM slope ≈ -1 vs -0.5).
    let cfg = cfg();
    let pairs = cfg.draw_pairs();
    let ns = [16usize, 64, 256, 1024];
    let xs: Vec<f64> = ns.iter().map(|&n| n as f64).collect();
    for op in Op::ALL {
        let bias = |scheme: Scheme| -> Vec<f64> {
            ns.iter()
                .map(|&n| evaluate(scheme, op, n, &pairs, &cfg).bias_abs)
                .collect()
        };
        let det = bias(Scheme::DeterministicVariant);
        let dit = bias(Scheme::Dither);
        let sto = bias(Scheme::Stochastic);
        for i in 0..ns.len() {
            assert!(
                dit[i] < det[i],
                "{op:?} N={}: dither |bias| {} vs deterministic {}",
                ns[i],
                dit[i],
                det[i]
            );
        }
        let s_dit = loglog_slope(&xs, &dit).unwrap();
        let s_sto = loglog_slope(&xs, &sto).unwrap();
        assert!(
            s_dit < s_sto - 0.25,
            "{op:?}: dither bias slope {s_dit} should be steeper than stochastic {s_sto}"
        );
    }
}

#[test]
fn evaluation_grid_is_bit_identical_across_kernels() {
    // The bitstream evaluation pipeline (encode → AND/MUX → popcount
    // estimate) consumes its RNG streams identically no matter which
    // kernel runs the word loops, so the *entire* (op, scheme) grid —
    // stochastic schemes included — must reproduce exactly, not just in
    // distribution, under each kernel.
    use dither::kernels::{self, KernelId};
    let cfg = EvalConfig {
        pairs: 24,
        trials: 40,
        seed: 0xCE41,
    };
    let pairs = cfg.draw_pairs();
    let mut grids: Vec<Vec<(f64, f64)>> = Vec::new();
    for id in KernelId::ALL {
        kernels::select(id);
        let mut grid = Vec::new();
        for op in Op::ALL {
            for scheme in Scheme::ALL {
                let r = evaluate(scheme, op, 96, &pairs, &cfg);
                grid.push((r.emse, r.bias_abs));
            }
        }
        grids.push(grid);
    }
    kernels::select(kernels::auto_detect());
    for g in &grids[1..] {
        assert_eq!(g, &grids[0], "evaluation grid varies with the kernel");
    }
}

#[test]
fn deterministic_variant_needs_single_trial() {
    // Footnote 2: the deterministic estimate never changes across trials.
    let cfg1 = EvalConfig {
        pairs: 40,
        trials: 1,
        seed: 9,
    };
    let cfg2 = EvalConfig {
        pairs: 40,
        trials: 50,
        seed: 9,
    };
    let pairs = cfg1.draw_pairs();
    for op in Op::ALL {
        let a = evaluate(Scheme::DeterministicVariant, op, 64, &pairs, &cfg1);
        let b = evaluate(Scheme::DeterministicVariant, op, 64, &pairs, &cfg2);
        assert_eq!(a.emse, b.emse, "{op:?}");
        assert_eq!(a.bias_abs, b.bias_abs, "{op:?}");
    }
}
