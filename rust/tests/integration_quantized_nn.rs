//! Integration: data → trainer → quantized inference, reproducing the
//! §VII–§VIII accuracy shapes at test scale.

use dither::data::{Dataset, Task};
use dither::linalg::Variant;
use dither::nn::{quantized_accuracy, ActivationRanges, Mlp, QuantInferenceConfig};
use dither::rounding::SchemeId;
use dither::train::{train, TrainConfig};
use dither::util::rng::Xoshiro256pp;

fn trained_digits(train_n: usize) -> (Mlp, Dataset) {
    let train_set = Dataset::synthesize(Task::Digits, train_n, 0xBEEF);
    let test_set = Dataset::synthesize(Task::Digits, 300, 0xF00D);
    let mut rng = Xoshiro256pp::new(1);
    let mut mlp = Mlp::single_layer(784, 10, &mut rng);
    train(
        &mut mlp,
        &train_set,
        &TrainConfig {
            epochs: 8,
            batch_size: 64,
            lr: 0.15,
            momentum: 0.9,
            seed: 2,
            verbose: false,
        },
    );
    mlp.normalize_weights();
    (mlp, test_set)
}

#[test]
fn float_model_learns_the_synthetic_task() {
    let (mlp, test) = trained_digits(1500);
    let acc = mlp.accuracy(&test.images, &test.labels);
    assert!(acc > 0.75, "float accuracy {acc} too low — task or trainer broken");
}

#[test]
fn high_k_quantized_matches_float_for_all_placements() {
    let (mlp, test) = trained_digits(1200);
    let float_acc = mlp.accuracy(&test.images, &test.labels);
    let ranges = ActivationRanges::calibrate(&mlp, &test.images);
    for variant in Variant::ALL {
        for mode in SchemeId::PAPER {
            let qcfg = QuantInferenceConfig {
                bits: 8,
                mode,
                variant,
                seed: 5,
            };
            let acc = quantized_accuracy(&mlp, &test.images, &test.labels, &ranges, &qcfg);
            assert!(
                acc > float_acc - 0.05,
                "{variant:?}/{mode:?} k=8: {acc} vs float {float_acc}"
            );
        }
    }
}

#[test]
fn fig9_shape_small_k_ordering() {
    // Figs 9/13: at k=1 deterministic collapses (pixels ∈ [0,1] inside the
    // [-1,1] quantizer all round to +1); dither/stochastic stay usable.
    let (mlp, test) = trained_digits(1200);
    let ranges = ActivationRanges::calibrate(&mlp, &test.images);
    let acc = |mode: SchemeId, k: u32, variant: Variant| -> f64 {
        let trials = if mode == SchemeId::Deterministic { 1 } else { 4 };
        (0..trials)
            .map(|t| {
                let qcfg = QuantInferenceConfig {
                    bits: k,
                    mode,
                    variant,
                    seed: 100 + t,
                };
                quantized_accuracy(&mlp, &test.images, &test.labels, &ranges, &qcfg)
            })
            .sum::<f64>()
            / trials as f64
    };
    // Per-partial at k=1: repeated roundings per element keep the signal.
    // Separate at k=2: one rounding per element needs one more bit before
    // the unbiased-vs-deterministic gap is decisive (paper: "for small
    // k > 1" in the separate-quantization figures).
    for (variant, k) in [(Variant::PerPartial, 1), (Variant::Separate, 2)] {
        let det = acc(SchemeId::Deterministic, k, variant);
        let dit = acc(SchemeId::Dither, k, variant);
        let sto = acc(SchemeId::Stochastic, k, variant);
        assert!(dit > det + 0.15, "{variant:?}: dither {dit} vs det {det} at k={k}");
        assert!(sto > det + 0.15, "{variant:?}: stochastic {sto} vs det {det} at k={k}");
        // Dither ≈ stochastic in mean (within a few points).
        assert!(
            (dit - sto).abs() < 0.12,
            "{variant:?}: dither {dit} ≈ stochastic {sto}"
        );
    }
}

#[test]
fn fig10_shape_dither_variance_not_higher() {
    // Fig 10: dither rounding's accuracy variance ≤ stochastic rounding's.
    let (mlp, test) = trained_digits(1200);
    let ranges = ActivationRanges::calibrate(&mlp, &test.images);
    let variance = |mode: SchemeId| -> f64 {
        let accs: Vec<f64> = (0..12)
            .map(|t| {
                let qcfg = QuantInferenceConfig {
                    bits: 2,
                    mode,
                    variant: Variant::PerPartial,
                    seed: 500 + t,
                };
                quantized_accuracy(&mlp, &test.images, &test.labels, &ranges, &qcfg)
            })
            .collect();
        let mean = accs.iter().sum::<f64>() / accs.len() as f64;
        accs.iter().map(|a| (a - mean).powi(2)).sum::<f64>() / (accs.len() - 1) as f64
    };
    let v_dit = variance(SchemeId::Dither);
    let v_sto = variance(SchemeId::Stochastic);
    assert!(
        v_dit <= v_sto * 1.5,
        "dither accuracy variance {v_dit} should not exceed stochastic {v_sto} materially"
    );
}

#[test]
fn fashion_mlp_three_layer_pipeline() {
    // The §VIII pipeline end-to-end on the harder task (reduced scale).
    let train_set = Dataset::synthesize(Task::Fashion, 1500, 0xFA);
    let test_set = Dataset::synthesize(Task::Fashion, 250, 0xFB);
    let mut rng = Xoshiro256pp::new(3);
    let mut mlp = Mlp::three_layer(784, 64, 32, 10, &mut rng);
    train(
        &mut mlp,
        &train_set,
        &TrainConfig {
            epochs: 10,
            batch_size: 64,
            lr: 0.08,
            momentum: 0.9,
            seed: 4,
            verbose: false,
        },
    );
    mlp.normalize_weights();
    let float_acc = mlp.accuracy(&test_set.images, &test_set.labels);
    assert!(float_acc > 0.5, "fashion float accuracy {float_acc}");
    let ranges = ActivationRanges::calibrate(&mlp, &test_set.images);
    // k=8 separate ≈ float (the §VIII working regime).
    let qcfg = QuantInferenceConfig {
        bits: 8,
        mode: SchemeId::Dither,
        variant: Variant::Separate,
        seed: 6,
    };
    let acc8 = quantized_accuracy(&mlp, &test_set.images, &test_set.labels, &ranges, &qcfg);
    assert!(
        acc8 > float_acc - 0.07,
        "fashion k=8 dither {acc8} vs float {float_acc}"
    );
}
