//! The paper's statistical claims, measured at *serving granularity*:
//! requests flow through a real [`Engine`] with shadow sampling on, and
//! the assertions read what the fidelity estimators report — exactly what
//! an operator sees in `stats.fidelity`.
//!
//! The model is controlled so the claims are forced, not incidental: a
//! single dense layer whose weights all sit exactly on a quantizer level
//! (every scheme encodes them without error — the measured error is purely
//! activation rounding) and narrow-range inputs in `[0.05, 0.45]` inside
//! the paper's fixed `[-1, 1]` input quantizer. At `k = 1` deterministic
//! rounding then maps *every* pixel to `+1` — the §VII regime where its
//! bias is catastrophic while the unbiased schemes keep the signal in
//! expectation.

use dither::coordinator::Engine;
use dither::fidelity::{choose, prior_mse, FidelityShard, MIN_SAMPLES};
use dither::linalg::Matrix;
use dither::nn::{ActivationRanges, Mlp};
use dither::rounding::SchemeId;
use dither::train::{ModelSpec, Zoo, ZooModel};
use dither::util::rng::Xoshiro256pp;
use std::sync::Arc;

const IN_DIM: usize = 64;
const CLASSES: usize = 4;
const BATCH: usize = 32;
const TRIALS: usize = 25;

/// A batch of narrow-range images: every pixel in `[0.05, 0.45]`.
fn narrow_batch(rows: usize, seed: u64) -> Matrix {
    let mut rng = Xoshiro256pp::new(seed);
    Matrix::from_fn(rows, IN_DIM, |_, _| rng.uniform(0.05, 0.45))
}

/// Zoo serving one controlled model under the `digits_linear` wire name:
/// all weights `0.5` with weight range `0.5`, so `scale(w)` lands exactly
/// on the top quantizer level and the weight side is error-free under
/// every scheme at every `k`.
fn controlled_zoo() -> Arc<Zoo> {
    let mut rng = Xoshiro256pp::new(3);
    let mut mlp = Mlp::single_layer(IN_DIM, CLASSES, &mut rng);
    mlp.layers[0].weights = Matrix::from_vec(IN_DIM, CLASSES, vec![0.5; IN_DIM * CLASSES]);
    mlp.layers[0].bias = vec![0.0; CLASSES];
    let ranges = ActivationRanges::calibrate(&mlp, &narrow_batch(8, 5));
    let model = ZooModel {
        spec: ModelSpec::DigitsLinear,
        mlp,
        ranges,
        float_accuracy: 0.0,
    };
    Arc::new(Zoo::from_models(vec![model]))
}

/// Drive `TRIALS` shadowed batches of the paper's trio at `k` through a
/// fresh engine and return its estimator table.
fn measure(k: u32, engine_seed: u64) -> Arc<FidelityShard> {
    let sink = Arc::new(FidelityShard::new());
    let engine = Engine::from_zoo(controlled_zoo(), engine_seed).with_shadow(1.0, sink.clone());
    let x = narrow_batch(BATCH, 99);
    let rows: Vec<&[f64]> = (0..x.rows).map(|i| x.row(i)).collect();
    for mode in SchemeId::PAPER {
        for _ in 0..TRIALS {
            engine
                .infer_batch("digits_linear", k, mode, &rows)
                .expect("controlled model serves");
        }
    }
    sink
}

/// Drive `TRIALS` shadowed batches of exactly one scheme at `k` — nothing
/// else touches the estimator, so any warm cell belongs to that scheme.
fn measure_one(mode: SchemeId, k: u32, engine_seed: u64) -> Arc<FidelityShard> {
    let sink = Arc::new(FidelityShard::new());
    let engine = Engine::from_zoo(controlled_zoo(), engine_seed).with_shadow(1.0, sink.clone());
    let x = narrow_batch(BATCH, 99);
    let rows: Vec<&[f64]> = (0..x.rows).map(|i| x.row(i)).collect();
    for _ in 0..TRIALS {
        engine
            .infer_batch("digits_linear", k, mode, &rows)
            .expect("controlled model serves");
    }
    sink
}

#[test]
fn bias_vanishes_for_unbiased_schemes_but_not_deterministic_at_small_k() {
    let sink = measure(1, 11);
    let slot = ModelSpec::DigitsLinear.index();
    let det = sink.estimate(slot, SchemeId::Deterministic, 1);
    let dit = sink.estimate(slot, SchemeId::Dither, 1);
    let sto = sink.estimate(slot, SchemeId::Stochastic, 1);
    for (name, est) in [("det", &det), ("dither", &dit), ("stochastic", &sto)] {
        assert!(
            est.samples >= MIN_SAMPLES,
            "{name}: {} samples should exceed the controller's warm threshold",
            est.samples
        );
    }
    // Deterministic rounding at k=1 maps every narrow-range pixel to +1,
    // and the all-positive weights turn that into a strongly positive
    // per-logit offset (analytically ≈ 0.5 · 64 · 0.75 = 24).
    assert!(det.bias > 1.0, "deterministic bias {} should be large", det.bias);
    // The unbiased schemes' measured |bias| shrinks toward 0 — orders of
    // magnitude below deterministic (their SEM at ≥3000 samples is ≪ 1).
    assert!(
        dit.bias.abs() < det.bias.abs() * 0.05,
        "dither bias {} vs deterministic {}",
        dit.bias,
        det.bias
    );
    assert!(
        sto.bias.abs() < det.bias.abs() * 0.05,
        "stochastic bias {} vs deterministic {}",
        sto.bias,
        det.bias
    );
}

#[test]
fn mse_ordering_matches_the_paper_at_matched_k() {
    let sink = measure(1, 17);
    let slot = ModelSpec::DigitsLinear.index();
    let det = sink.estimate(slot, SchemeId::Deterministic, 1).mse();
    let dit = sink.estimate(slot, SchemeId::Dither, 1).mse();
    let sto = sink.estimate(slot, SchemeId::Stochastic, 1).mse();
    // Dither ≤ stochastic at matched N (period-stratified rounding errors
    // cancel within each contraction window), both far below the biased
    // deterministic scheme in this regime.
    assert!(dit <= sto * 1.1, "dither mse {dit} should not exceed stochastic {sto}");
    assert!(
        det > 4.0 * dit.max(sto),
        "deterministic mse {det} should dwarf dither {dit} / stochastic {sto}"
    );
}

#[test]
fn measured_mse_falls_with_bit_width() {
    let coarse = measure(1, 23);
    let fine = measure(4, 23);
    let slot = ModelSpec::DigitsLinear.index();
    let mse1 = coarse.estimate(slot, SchemeId::Dither, 1).mse();
    let mse4 = fine.estimate(slot, SchemeId::Dither, 4).mse();
    assert!(mse4 < mse1 / 4.0, "dither mse must fall with k: k=1 {mse1} vs k=4 {mse4}");
}

#[test]
fn auto_controller_hands_off_from_prior_to_live_measurements() {
    // Budget chosen so the prior says deterministic k=1 fits, but the
    // *measured* deterministic k=1 MSE (≈ 576 in this regime) blows it
    // while dither k=1 sails under — the choice must move once the cells
    // are warm, using only what shadow sampling actually measured.
    let budget = prior_mse(SchemeId::Deterministic, 1) * 1.02;
    let slot = ModelSpec::DigitsLinear.index();
    let cold = choose(&FidelityShard::new(), slot, budget);
    assert_eq!(
        (cold.scheme, cold.k, cold.measured),
        (SchemeId::Deterministic, 1, false),
        "cold controller must run on the prior"
    );
    let sink = measure(1, 31);
    assert!(
        sink.estimate(slot, SchemeId::Deterministic, 1).mse() > budget,
        "the measured deterministic MSE must exceed the prior-feasible budget"
    );
    let warm = choose(&sink, slot, budget);
    assert_eq!(
        (warm.scheme, warm.k),
        (SchemeId::Dither, 1),
        "warm controller must move to the cheapest scheme that measures under budget: {warm:?}"
    );
    assert!(warm.measured);
    assert!(warm.predicted_mse <= budget);
    // Deterministic given the estimator state.
    assert_eq!(warm, choose(&sink, slot, budget));
}

#[test]
fn zoo_scheme_acquires_measured_cells_and_wins_auto_resolution() {
    // A literature scheme is a first-class citizen of the serving stack:
    // shadow sampling fills its (model, scheme, k) estimator cell, and
    // once warm the measured estimate makes it auto-eligible — the
    // controller hands an auto request to sr2 when it is the first
    // candidate whose *measured* MSE fits a budget every prior flunks.
    let sink = measure_one(SchemeId::Sr2, 2, 41);
    let slot = ModelSpec::DigitsLinear.index();
    let est = sink.estimate(slot, SchemeId::Sr2, 2);
    assert!(
        est.samples >= MIN_SAMPLES,
        "sr2 cell holds {} samples, needs {MIN_SAMPLES} to go live",
        est.samples
    );
    let budget = est.mse() * 2.0;
    // Self-diagnosing guards: the budget must sit below every candidate
    // the controller walks before the measured sr2 cell — the cheapest
    // k=1 prior (srvb) and the cheapest k=2 priors (det/dither) — so
    // only the live measurement can satisfy it. The 64-wide controlled
    // model keeps measured logit errors far under the 784-wide priors.
    assert!(
        budget < prior_mse(SchemeId::SrVb, 1)
            && budget < prior_mse(SchemeId::Deterministic, 2),
        "measured sr2 mse {} is not far enough below the priors",
        est.mse()
    );
    let choice = choose(&sink, slot, budget);
    assert_eq!(
        (choice.scheme, choice.k, choice.measured),
        (SchemeId::Sr2, 2, true),
        "{choice:?}"
    );
    assert!(choice.predicted_mse <= budget);
}
