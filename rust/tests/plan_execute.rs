//! Property tests for the plan → execute split: the prepared
//! (weight-plans-cached) inference path must be *bit-identical* to the
//! direct plan-per-call path wherever the refactor promises it, and
//! *distribution-equivalent* everywhere else.
//!
//! Contract under test (see `nn/prepared.rs`):
//!
//! * deterministic mode: bit-identical, independent of both the prepare
//!   seed and the per-call seed;
//! * stochastic mode: bit-identical given the same per-call seed (weight
//!   draws stay fresh per request);
//! * dither mode under `Separate`: the weight draw is frozen at prepare
//!   time, so outputs are distribution-equivalent — same per-logit mean
//!   over many trials, comparable trial-to-trial spread — rather than
//!   bitwise equal;
//! * dither mode under `InputOnce`/`PerPartial`: the weight side is
//!   planned per call (batch-sized sweep period), so outputs are
//!   bit-identical given the per-call seed.

use dither::linalg::{Matrix, Variant};
use dither::nn::{quantized_forward, ActivationRanges, Mlp, PreparedModel, QuantInferenceConfig};
use dither::rounding::SchemeId;
use dither::util::rng::Xoshiro256pp;
use dither::util::stats::Welford;

/// A small normalized network and a batch of inputs in the paper's
/// narrow-range regime (pixels well inside the [-1, 1] quantizer).
fn toy(layers: usize, seed: u64) -> (Mlp, Matrix, ActivationRanges) {
    let mut rng = Xoshiro256pp::new(seed);
    let mut mlp = match layers {
        1 => Mlp::single_layer(16, 4, &mut rng),
        _ => Mlp::three_layer(16, 12, 8, 4, &mut rng),
    };
    mlp.normalize_weights();
    let mut x = Matrix::zeros(6, 16);
    for i in 0..6 {
        for j in 0..16 {
            x.set(i, j, rng.uniform(0.05, 0.85));
        }
    }
    let ranges = ActivationRanges::calibrate(&mlp, &x);
    (mlp, x, ranges)
}

#[test]
fn prepared_deterministic_is_bit_identical_across_variants() {
    // The acceptance criterion: plan-based deterministic forward equals
    // the direct path exactly — every placement, several bit widths, and
    // independent of prepare/call seeds.
    let (mlp, x, ranges) = toy(3, 1);
    for variant in Variant::ALL {
        for bits in [1u32, 3, 6, 10] {
            let cfg = QuantInferenceConfig {
                bits,
                mode: SchemeId::Deterministic,
                variant,
                seed: 99,
            };
            let direct = quantized_forward(&mlp, &x, &ranges, &cfg);
            for prep_seed in [0u64, 7] {
                let prepared = PreparedModel::prepare(
                    &mlp,
                    bits,
                    SchemeId::Deterministic,
                    variant,
                    prep_seed,
                );
                for call_seed in [99u64, 5000] {
                    let planned = prepared.forward(&mlp, &x, &ranges, call_seed);
                    assert_eq!(
                        direct.data(),
                        planned.data(),
                        "{variant:?} bits={bits} prep={prep_seed} call={call_seed}"
                    );
                }
            }
        }
    }
}

#[test]
fn prepared_stochastic_is_bit_identical_given_call_seed() {
    // Stochastic weight plans are never frozen: with the same per-call
    // seed the prepared path must reproduce the direct path bit for bit
    // (the plan only hoists seed-independent tables).
    let (mlp, x, ranges) = toy(3, 2);
    for variant in Variant::ALL {
        let prepared = PreparedModel::prepare(&mlp, 4, SchemeId::Stochastic, variant, 77);
        for trial in 0..50u64 {
            let cfg = QuantInferenceConfig {
                bits: 4,
                mode: SchemeId::Stochastic,
                variant,
                seed: trial,
            };
            let direct = quantized_forward(&mlp, &x, &ranges, &cfg);
            let planned = prepared.forward(&mlp, &x, &ranges, trial);
            assert_eq!(direct.data(), planned.data(), "{variant:?} trial={trial}");
        }
    }
}

#[test]
fn prepared_dither_per_partial_placements_match_direct_bitwise() {
    // Under InputOnce/PerPartial the weight operand's dither period is the
    // batch size, which cannot be prebuilt — PreparedModel plans those
    // layers per call, so the output must equal the direct path bit for
    // bit (same seeds, same batch-derived period).
    let (mlp, x, ranges) = toy(3, 6);
    for variant in [Variant::InputOnce, Variant::PerPartial] {
        let prepared = PreparedModel::prepare(&mlp, 4, SchemeId::Dither, variant, 55);
        for trial in 0..20u64 {
            let cfg = QuantInferenceConfig {
                bits: 4,
                mode: SchemeId::Dither,
                variant,
                seed: trial,
            };
            let direct = quantized_forward(&mlp, &x, &ranges, &cfg);
            let planned = prepared.forward(&mlp, &x, &ranges, trial);
            assert_eq!(direct.data(), planned.data(), "{variant:?} trial={trial}");
        }
    }
}

/// Per-cell trial statistics of a forward-pass sampler.
fn collect(
    trials: u64,
    cells: usize,
    mut forward: impl FnMut(u64) -> Matrix,
) -> (Vec<f64>, Vec<f64>) {
    let mut stats = vec![Welford::new(); cells];
    for t in 0..trials {
        let out = forward(t);
        assert_eq!(out.data().len(), cells);
        for (w, &v) in stats.iter_mut().zip(out.data()) {
            w.push(v);
        }
    }
    let means = stats.iter().map(Welford::mean).collect();
    let sds = stats.iter().map(Welford::stddev).collect();
    (means, sds)
}

#[test]
fn prepared_dither_is_distribution_equivalent() {
    // Dither weight plans freeze one §II-D draw, so the prepared path is
    // not bitwise equal to the direct path — but over ≥1k trials the
    // per-logit means must agree (both are unbiased up to the frozen
    // draw's sub-step residue) and the trial-to-trial spread must stay
    // the same order (the direct path merely adds the weight-side noise
    // component on top of the shared activation-side noise).
    let (mlp, x, ranges) = toy(1, 3);
    let trials = 1200u64;
    let cells = 6 * 4;
    let prepared = PreparedModel::prepare(&mlp, 10, SchemeId::Dither, Variant::Separate, 21);
    let (mean_p, sd_p) = collect(trials, cells, |t| {
        prepared.forward(&mlp, &x, &ranges, 10_000 + t)
    });
    let (mean_d, sd_d) = collect(trials, cells, |t| {
        let cfg = QuantInferenceConfig {
            bits: 10,
            mode: SchemeId::Dither,
            variant: Variant::Separate,
            seed: 10_000 + t,
        };
        quantized_forward(&mlp, &x, &ranges, &cfg)
    });
    // Logits are O(1) sums of 16 products; at k=10 the quantizer step is
    // 2/1023 ≈ 0.002, so even a fully adversarial frozen weight draw moves
    // a logit by ≤ 16·0.85·step ≈ 0.027 — the 0.1 tolerance has ~4×
    // headroom while still ruling out any systematic divergence.
    for (c, (mp, md)) in mean_p.iter().zip(&mean_d).enumerate() {
        assert!(
            (mp - md).abs() < 0.1,
            "cell {c}: planned mean {mp} vs direct mean {md}"
        );
    }
    let avg = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    let (sp, sd) = (avg(&sd_p), avg(&sd_d));
    assert!(
        sp <= sd * 2.0 + 1e-3,
        "planned spread {sp} should not exceed direct spread {sd}"
    );
    assert!(
        sd <= sp * 4.0 + 1e-3,
        "direct spread {sd} should stay comparable to planned {sp}"
    );
}

#[test]
fn prepared_stochastic_distribution_matches_over_trials() {
    // The same ≥1k-trial statistic for stochastic mode. Bitwise identity
    // per trial (tested above) makes this exact; keeping the statistical
    // form documents the distribution-equivalence contract symmetrically.
    let (mlp, x, ranges) = toy(1, 4);
    let trials = 1000u64;
    let cells = 6 * 4;
    let mode = SchemeId::Stochastic;
    let prepared = PreparedModel::prepare(&mlp, 6, mode, Variant::Separate, 33);
    let (mean_p, sd_p) = collect(trials, cells, |t| {
        prepared.forward(&mlp, &x, &ranges, 44_000 + t)
    });
    let (mean_d, sd_d) = collect(trials, cells, |t| {
        let cfg = QuantInferenceConfig {
            bits: 6,
            mode,
            variant: Variant::Separate,
            seed: 44_000 + t,
        };
        quantized_forward(&mlp, &x, &ranges, &cfg)
    });
    for ((mp, md), (sp, sd)) in mean_p.iter().zip(&mean_d).zip(sd_p.iter().zip(&sd_d)) {
        assert!((mp - md).abs() < 1e-12, "means must match exactly");
        assert!((sp - sd).abs() < 1e-12, "spreads must match exactly");
    }
}

#[test]
fn forward_is_bit_identical_across_kernels_for_every_scheme() {
    // The kernel layer's contract: every scheme's rounding bits are pure
    // counter-hash functions of their coordinates and every kernel keeps
    // per-cell accumulation order, so the full quantized forward pass —
    // not just deterministic mode — is bitwise invariant under the
    // process-global kernel switch, for both the direct and the prepared
    // (plan-cached) path. A plan built under one kernel must also execute
    // identically under another.
    use dither::kernels::{self, KernelId};
    let (mlp, x, ranges) = toy(3, 8);
    for mode in SchemeId::ALL {
        for variant in Variant::ALL {
            let cfg = QuantInferenceConfig {
                bits: 4,
                mode,
                variant,
                seed: 13,
            };
            let mut direct: Vec<Vec<f64>> = Vec::new();
            let mut planned: Vec<Vec<f64>> = Vec::new();
            for id in KernelId::ALL {
                kernels::select(id);
                direct.push(quantized_forward(&mlp, &x, &ranges, &cfg).data().to_vec());
                let prepared = PreparedModel::prepare(&mlp, 4, mode, variant, 21);
                planned.push(prepared.forward(&mlp, &x, &ranges, 13).data().to_vec());
            }
            // Cross-kernel plan execution: prepare under scalar, run wide.
            kernels::select(KernelId::Scalar);
            let prepared = PreparedModel::prepare(&mlp, 4, mode, variant, 21);
            kernels::select(KernelId::Wide);
            let crossed = prepared.forward(&mlp, &x, &ranges, 13).data().to_vec();
            kernels::select(kernels::auto_detect());
            for d in &direct[1..] {
                assert_eq!(d, &direct[0], "{mode:?}/{variant:?} direct varies with kernel");
            }
            for p in &planned[1..] {
                assert_eq!(p, &planned[0], "{mode:?}/{variant:?} planned varies with kernel");
            }
            assert_eq!(
                crossed, planned[0],
                "{mode:?}/{variant:?} scalar-built plan must execute identically under wide"
            );
        }
    }
}

#[test]
fn prepared_forward_is_reproducible_per_seed() {
    let (mlp, x, ranges) = toy(3, 5);
    for mode in SchemeId::PAPER {
        let prepared = PreparedModel::prepare(&mlp, 5, mode, Variant::Separate, 9);
        let a = prepared.forward(&mlp, &x, &ranges, 123);
        let b = prepared.forward(&mlp, &x, &ranges, 123);
        assert_eq!(a.data(), b.data(), "{mode:?}");
        if mode != SchemeId::Deterministic {
            let c = prepared.forward(&mlp, &x, &ranges, 124);
            assert_ne!(a.data(), c.data(), "{mode:?} must vary with the seed");
        }
    }
}
