"""AOT lowering: JAX models -> HLO text artifacts for the Rust runtime.

Interchange is HLO *text*, not a serialized ``HloModuleProto``: jax >= 0.5
emits protos with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot [--out-dir ../artifacts]

Emits one artifact per (model, batch size) plus ``manifest.json`` recording
each artifact's input signature, which the Rust runtime validates at load.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

#: Batch sizes baked into the serving artifacts (one executable each).
BATCH_SIZES = (1, 32, 256)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def scalar(dtype):
    return jax.ShapeDtypeStruct((), dtype)


def model_specs(batch):
    """(name, fn, example-arg specs, human input signature) per artifact."""
    i32 = jnp.int32
    u32 = jnp.uint32
    return [
        (
            f"digits_linear_b{batch}",
            model.digits_linear_forward,
            (
                f32(batch, 784),
                f32(784, 10),
                f32(10),
                scalar(i32),
                scalar(i32),
                scalar(u32),
            ),
            ["x(b,784)f32", "w(784,10)f32", "b(10)f32", "k()i32", "mode()i32", "seed()u32"],
        ),
        (
            f"fashion_mlp_b{batch}",
            model.fashion_mlp_forward,
            (
                f32(batch, 784),
                f32(784, 128),
                f32(128),
                f32(128, 64),
                f32(64),
                f32(64, 10),
                f32(10),
                scalar(i32),
                scalar(i32),
                scalar(u32),
                scalar(jnp.float32),
                scalar(jnp.float32),
            ),
            [
                "x(b,784)f32",
                "w1(784,128)f32",
                "b1(128)f32",
                "w2(128,64)f32",
                "b2(64)f32",
                "w3(64,10)f32",
                "b3(10)f32",
                "k()i32",
                "mode()i32",
                "seed()u32",
                "r1()f32",
                "r2()f32",
            ],
        ),
        (
            f"digits_linear_float_b{batch}",
            model.digits_linear_float,
            (f32(batch, 784), f32(784, 10), f32(10)),
            ["x(b,784)f32", "w(784,10)f32", "b(10)f32"],
        ),
    ]


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument(
        "--batches", default=",".join(str(b) for b in BATCH_SIZES),
        help="comma-separated batch sizes",
    )
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    batches = [int(b) for b in args.batches.split(",") if b]

    manifest = {"format": "hlo-text", "dither_n": model.DITHER_N, "artifacts": []}
    for batch in batches:
        for name, fn, specs, signature in model_specs(batch):
            lowered = jax.jit(fn).lower(*specs)
            text = to_hlo_text(lowered)
            fname = f"{name}.hlo.txt"
            path = os.path.join(args.out_dir, fname)
            with open(path, "w") as f:
                f.write(text)
            manifest["artifacts"].append(
                {
                    "name": name,
                    "file": fname,
                    "batch": batch,
                    "inputs": signature,
                    "outputs": ["logits(b,10)f32"],
                }
            )
            print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(args.out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()
