"""Layer 2: the JAX evaluation models, built on the Layer-1 Pallas kernels.

Two architectures, matching the paper's evaluation networks and the Rust
model zoo (`rust/src/train/zoo.rs`):

* ``digits_linear`` — single 784→10 softmax layer (§VII MNIST experiments).
* ``fashion_mlp``  — 784→128→64→10 ReLU MLP (§VIII Fashion experiments).

Every matmul is the quantized Pallas kernel with `Separate` placement:
weights are quantized once per call ("precoded", §VI), activations are
quantized inside the fused matmul kernel. Quantizer bit-width ``k``,
rounding ``mode`` (0=deterministic, 1=stochastic, 2=dither), ``seed`` and
the calibrated hidden activation half-ranges are *runtime* scalars, so one
AOT artifact serves every experimental configuration.

Weights are runtime inputs too: the Rust coordinator feeds weights trained
by its own SGD trainer — Python never sees training or serving traffic.
"""

import jax.numpy as jnp

from .kernels.quant_matmul import quant_matmul_pallas, quantize_pallas

#: Dither period baked into the kernels (paper's N; see DESIGN.md).
DITHER_N = 64


def _quant_dense(h, w, b, k, mode, seed, lo_a, hi_a, relu):
    """One quantized dense layer: round weights once, fused matmul, bias.

    Weights sweep dither positions along axis 0 (their contraction axis);
    the activation block sweeps axis 1 inside the fused matmul kernel.
    """
    w_hat = quantize_pallas(
        w, k, mode, seed + jnp.uint32(0xB1B1), -1.0, 1.0, n=DITHER_N, axis=0
    )
    out = quant_matmul_pallas(h, w_hat, k, mode, seed, lo_a, hi_a, n=DITHER_N)
    out = out + b[None, :]
    if relu:
        out = jnp.maximum(out, 0.0)
    return out


def digits_linear_forward(x, w, b, k, mode, seed):
    """Quantized single-layer classifier. Returns logits ``(batch, 10)``.

    Inputs: ``x (batch,784) f32`` in [0,1]; ``w (784,10) f32`` in [-1,1];
    ``b (10,) f32``; scalars ``k i32``, ``mode i32``, ``seed u32``.
    The input shares the weight quantizer's [-1, 1] range (the paper's
    deliberately wasteful setting).
    """
    return _quant_dense(x, w, b, k, mode, seed, -1.0, 1.0, relu=False)


def fashion_mlp_forward(x, w1, b1, w2, b2, w3, b3, k, mode, seed, r1, r2):
    """Quantized 3-layer MLP. Returns logits ``(batch, 10)``.

    ``r1``/``r2`` are the calibrated half-ranges of the two hidden
    activations (runtime f32 scalars supplied by the Rust coordinator).
    """
    h = _quant_dense(x, w1, b1, k, mode, seed, -1.0, 1.0, relu=True)
    h = _quant_dense(h, w2, b2, k, mode, seed + jnp.uint32(1), -r1, r1, relu=True)
    return _quant_dense(h, w3, b3, k, mode, seed + jnp.uint32(2), -r2, r2, relu=False)


def digits_linear_float(x, w, b):
    """Full-precision reference forward (baseline artifact)."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32) + b[None, :]


def fashion_mlp_float(x, w1, b1, w2, b2, w3, b3):
    """Full-precision 3-layer reference forward."""
    h = jnp.maximum(jnp.dot(x, w1, preferred_element_type=jnp.float32) + b1[None, :], 0.0)
    h = jnp.maximum(jnp.dot(h, w2, preferred_element_type=jnp.float32) + b2[None, :], 0.0)
    return jnp.dot(h, w3, preferred_element_type=jnp.float32) + b3[None, :]
