"""Counter-based PRNG used inside the Pallas kernels (Layer 1).

Stateless uniform randomness from (seed, counter) pairs via a murmur3-style
uint32 finalizer. Being counter-based means the kernel needs no PRNG state
threaded through the grid: every (element, use) pair hashes its own index,
mirroring `counter_hash` in `rust/src/util/rng.rs` (structurally — the Rust
side uses the 64-bit SplitMix finalizer; both are stateless mixes of
seed and counter).
"""

import jax.numpy as jnp
import numpy as np

# numpy scalars, not jnp arrays: module-level jnp arrays would be captured
# as constants (rejected by pallas_call), and bare Python ints this large
# overflow JAX's weak-int32 parsing.
_C1 = np.uint32(0x9E3779B9)
_C2 = np.uint32(0x85EBCA6B)
_C3 = np.uint32(0xC2B2AE35)


def hash_u32(seed, counter):
    """Mix a uint32 seed with a uint32 counter array -> uint32 array.

    murmur3 fmix32 applied to ``seed ^ (counter * phi32)``; passes basic
    avalanche expectations (each input bit flips ~half the output bits),
    which is plenty for rounding decisions.
    """
    seed = seed.astype(jnp.uint32) if hasattr(seed, "astype") else jnp.uint32(seed)
    x = counter.astype(jnp.uint32) * _C1 ^ seed
    x = (x ^ (x >> 16)) * _C2
    x = (x ^ (x >> 13)) * _C3
    return x ^ (x >> 16)


def uniform01(seed, counter):
    """Uniform float32 in [0, 1) from (seed, counter)."""
    return hash_u32(seed, counter).astype(jnp.float32) * (1.0 / 2**32)
