"""Layer 1: Pallas kernels for k-bit quantized matmul with dither rounding.

Two kernels:

* :func:`quantize_pallas` — elementwise k-bit quantization with a runtime-
  selectable rounding mode (deterministic / stochastic / dither), gridded
  over row blocks. This is the `Separate`-placement building block (§VIII).
* :func:`quant_matmul_pallas` — the fused hot path: per grid step an
  ``(TI × q)`` activation block is quantized on the VPU and multiplied
  against the (pre-quantized, resident) weight matrix on the MXU.

TPU mapping (DESIGN.md §Hardware-Adaptation): quantization is elementwise
VPU work on VMEM-resident blocks; the MXU consumes the *dequantized* f32
blocks. Rounding randomness is a counter hash of the element's flat index —
no PRNG state crosses grid steps, so the grid can be executed in any order
(exactly how dither rounding's sequential index generalizes to a
data-parallel device). ``interpret=True`` everywhere: the CPU PJRT client
cannot run Mosaic custom-calls; real-TPU numbers are estimated in DESIGN.md.

The quantizer parameters ``k`` (bit width), ``mode`` (rounding scheme),
``seed``, and the source range ``(lo, hi)`` are all *runtime* inputs, so a
single compiled artifact serves every configuration the coordinator asks
for. All kernels share their arithmetic with ``ref.py`` (the pure-jnp
oracle); pytest asserts elementwise equality.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import prng, ref


def _quantize_block(x, k, mode, seed, lo, hi, n, row_base, cols, axis):
    """Shared in-kernel quantization arithmetic (same math as ref.py).

    ``row_base`` is the block's first *global* row (grid offset); dither
    positions stratify the contraction axis with a per-line rotation —
    see ``ref.dither_positions`` for the rationale.
    """
    levels = jnp.exp2(k) - 1.0
    step = (hi - lo) / levels
    s = jnp.clip((x - lo) / (hi - lo) * levels, 0.0, levels)
    fl = jnp.floor(s)
    frac = s - fl
    rows_idx = row_base + jax.lax.broadcasted_iota(jnp.uint32, x.shape, 0)
    cols_idx = jax.lax.broadcasted_iota(jnp.uint32, x.shape, 1)
    flat = rows_idx * jnp.uint32(cols) + cols_idx
    u = prng.uniform01(seed, flat)
    if axis == 1:
        rot = prng.hash_u32(seed + jnp.uint32(0x51), rows_idx)
        pos = (cols_idx + rot) % jnp.uint32(n)
    else:
        rot = prng.hash_u32(seed + jnp.uint32(0x51), cols_idx)
        pos = (rows_idx + rot) % jnp.uint32(n)
    bit = ref.round_bits(frac, mode, n, pos, u)
    return lo + (fl + bit.astype(jnp.float32)) * step


def _scalar_args(k, mode, seed, rng):
    """Normalize runtime scalars to the shapes the kernels expect."""
    k = jnp.asarray(k, jnp.int32).reshape(1)
    mode = jnp.asarray(mode, jnp.int32).reshape(1)
    seed = jnp.asarray(seed, jnp.uint32).reshape(1)
    rng = jnp.asarray(rng, jnp.float32).reshape(2)
    return k, mode, seed, rng


def _quantize_kernel(
    x_ref, k_ref, mode_ref, seed_ref, range_ref, o_ref, *, n, block_rows, cols, axis
):
    pid = pl.program_id(0)
    x = x_ref[...]
    k = k_ref[0].astype(jnp.float32)
    mode = mode_ref[0]
    seed = seed_ref[0].astype(jnp.uint32)
    lo = range_ref[0]
    hi = range_ref[1]
    row_base = pid.astype(jnp.uint32) * jnp.uint32(block_rows)
    o_ref[...] = _quantize_block(x, k, mode, seed, lo, hi, n, row_base, cols, axis)


def quantize_pallas(x, k, mode, seed, lo, hi, n=64, block_rows=128, axis=1):
    """Quantize ``x`` once per element with the k-bit quantizer (§VII).

    ``k``, ``mode``, ``seed``, ``lo``/``hi`` are runtime scalars; ``n`` (the
    dither period), the block shape and the dither sweep ``axis`` are
    static. Rows are processed in VMEM blocks of ``block_rows``.
    """
    rows, cols = x.shape
    k, mode, seed, rng = _scalar_args(k, mode, seed, jnp.stack([jnp.asarray(lo, jnp.float32), jnp.asarray(hi, jnp.float32)]))
    block_rows = min(block_rows, rows)
    grid = (pl.cdiv(rows, block_rows),)
    kernel = functools.partial(
        _quantize_kernel, n=n, block_rows=block_rows, cols=cols, axis=axis
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), k, mode, seed, rng)


def _matmul_kernel(
    x_ref, w_ref, k_ref, mode_ref, seed_ref, range_ref, o_ref, *, n, block_rows, q
):
    pid = pl.program_id(0)
    x = x_ref[...]
    w = w_ref[...]  # already quantized, VMEM-resident
    k = k_ref[0].astype(jnp.float32)
    mode = mode_ref[0]
    seed = seed_ref[0].astype(jnp.uint32)
    lo = range_ref[0]
    hi = range_ref[1]
    row_base = pid.astype(jnp.uint32) * jnp.uint32(block_rows)
    x_hat = _quantize_block(x, k, mode, seed, lo, hi, n, row_base, q, 1)
    # MXU consumes the dequantized block.
    o_ref[...] = jnp.dot(x_hat, w, preferred_element_type=jnp.float32)


def quant_matmul_pallas(x, w_hat, k, mode, seed, lo_a, hi_a, n=64, block_rows=128):
    """Fused quantize-and-matmul: ``quantize(x) @ w_hat``.

    ``w_hat`` must already be quantized (weights are rounded once and stay
    resident — §VI: "the weight can be precoded"). The activation block is
    quantized in-kernel and fed to the MXU.
    """
    p, q = x.shape
    q2, r = w_hat.shape
    assert q == q2, f"inner dims mismatch: {q} vs {q2}"
    k, mode, seed, rng = _scalar_args(k, mode, seed, jnp.stack([jnp.asarray(lo_a, jnp.float32), jnp.asarray(hi_a, jnp.float32)]))
    block_rows = min(block_rows, p)
    grid = (pl.cdiv(p, block_rows),)
    kernel = functools.partial(_matmul_kernel, n=n, block_rows=block_rows, q=q)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, q), lambda i: (i, 0)),
            pl.BlockSpec((q, r), lambda i: (0, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((block_rows, r), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((p, r), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), w_hat.astype(jnp.float32), k, mode, seed, rng)
