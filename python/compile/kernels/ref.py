"""Pure-jnp oracle for the quantized-matmul kernels (no Pallas).

Implements exactly the same arithmetic as ``quant_matmul.py`` — k-bit affine
quantization with deterministic / stochastic / dither rounding (paper §VII),
`Separate` placement (both operands rounded once, §VIII) — using plain
jax.numpy, so pytest can compare the Pallas kernel against it elementwise.

Rounding-mode encoding (shared with the kernel and the Rust runtime):
    0 = deterministic, 1 = stochastic, 2 = dither.
"""

import jax.numpy as jnp

from . import prng

MODE_DETERMINISTIC = 0
MODE_STOCHASTIC = 1
MODE_DITHER = 2


def dither_bit(frac, pos, u, n):
    """The dither-representation bit (paper §II-D) for residue ``frac``.

    ``pos`` is the (already randomized) index in the length-``n`` dither
    sequence; ``u`` a fresh uniform in [0,1). Lower branch (frac <= 1/2):
    ``n_l = floor(N·frac)`` sure ones plus Bernoulli(delta) elsewhere;
    upper branch: ``n_u = ceil(N·frac)`` Bernoulli(1-delta) plus sure zeros.
    """
    nf = jnp.float32(n)
    posf = pos.astype(jnp.float32)
    # Lower branch.
    n_l = jnp.floor(nf * frac)
    delta_l = jnp.where(n_l >= nf, 0.0, (nf * frac - n_l) / (nf - n_l))
    bit_l = jnp.logical_or(posf < n_l, u < delta_l)
    # Upper branch.
    n_u = jnp.ceil(nf * frac)
    delta_u = jnp.where(n_u <= 0, 0.0, (n_u - nf * frac) / n_u)
    bit_u = jnp.logical_and(posf < n_u, u < 1.0 - delta_u)
    return jnp.where(frac <= 0.5, bit_l, bit_u)


def round_bits(frac, mode, n, pos, u):
    """Rounding bit per element under ``mode`` (a traced scalar int)."""
    det = frac >= 0.5
    sto = u < frac
    dit = dither_bit(frac, pos, u, n)
    return jnp.where(
        mode == MODE_DETERMINISTIC, det, jnp.where(mode == MODE_STOCHASTIC, sto, dit)
    )


def dither_positions(shape, seed, n, axis):
    """Stratified dither positions for a 2-D element grid.

    Positions SWEEP the period along the matmul's *contraction* axis (the
    paper's global ``i_s`` counter semantics): every window of N contracted
    elements covers the whole dither sequence, so rounding errors cancel
    exactly where the matmul sums them. Each line perpendicular to the
    sweep gets its own random rotation — a single shared phase would give
    every row the same error pattern, coherently aligned with the other
    operand (worse than stochastic rounding; see EXPERIMENTS.md).

    ``axis=1``: sweep along each row (left/activation operand).
    ``axis=0``: sweep along each column (right/weight operand).
    """
    rows_idx = jnp.arange(shape[0], dtype=jnp.uint32)[:, None]
    cols_idx = jnp.arange(shape[1], dtype=jnp.uint32)[None, :]
    seed = jnp.asarray(seed, jnp.uint32)
    if axis == 1:
        rot = prng.hash_u32(seed + jnp.uint32(0x51), rows_idx)
        pos = (cols_idx + rot) % jnp.uint32(n)
    else:
        rot = prng.hash_u32(seed + jnp.uint32(0x51), cols_idx)
        pos = (rows_idx + rot) % jnp.uint32(n)
    return jnp.broadcast_to(pos, shape)


def quantize_once_ref(x, k, mode, seed, lo, hi, n=64, axis=1):
    """Quantize a matrix once per element (the `Separate` building block).

    ``k`` may be a traced scalar (int32); levels = 2^k - 1. Elements scale
    into [0, levels], the rounding bit picks floor vs ceil, and the result
    is dequantized back to source units. ``axis`` selects the dither sweep
    direction (see :func:`dither_positions`).
    """
    x = x.astype(jnp.float32)
    kf = jnp.asarray(k, jnp.float32)
    levels = jnp.exp2(kf) - 1.0
    lo = jnp.asarray(lo, jnp.float32)
    hi = jnp.asarray(hi, jnp.float32)
    step = (hi - lo) / levels
    s = jnp.clip((x - lo) / (hi - lo) * levels, 0.0, levels)
    fl = jnp.floor(s)
    frac = s - fl
    flat = jnp.arange(x.size, dtype=jnp.uint32).reshape(x.shape)
    u = prng.uniform01(seed, flat)
    pos = dither_positions(x.shape, seed, n, axis)
    bit = round_bits(frac, mode, n, pos, u)
    return lo + (fl + bit.astype(jnp.float32)) * step


def quant_matmul_ref(a, b, k, mode, seed, range_a, range_b, n=64):
    """`Separate`-placement quantized matmul oracle: round once, multiply.

    ``a`` sweeps along its rows (axis=1), ``b`` along its columns (axis=0) —
    both stratify the contraction dimension of ``a @ b``.
    """
    a_hat = quantize_once_ref(a, k, mode, seed, range_a[0], range_a[1], n, axis=1)
    b_hat = quantize_once_ref(
        b, k, mode, jnp.uint32(seed) + jnp.uint32(0xB1B1), range_b[0], range_b[1], n, axis=0
    )
    return jnp.dot(a_hat, b_hat, preferred_element_type=jnp.float32)
