"""Minimal drop-in for the slice of ``hypothesis`` the kernel tests use.

The offline test environment may not provide ``hypothesis``; this shim
implements ``@settings(max_examples=..., deadline=...)``, ``@given(**kw)``
and ``strategies.integers(lo, hi)`` by sampling a fixed number of random
cases from a seeded PRNG. It keeps the property-test *shape* (many sampled
cases per test) at the cost of hypothesis's shrinking and case database —
acceptable for a fallback; CI installs the real package when it can.
"""

import random

_SEED = 0xD17E


class _Integers:
    def __init__(self, lo, hi):
        self.lo, self.hi = lo, hi

    def sample(self, rng):
        return rng.randint(self.lo, self.hi)


class st:  # noqa: N801 - mirrors `from hypothesis import strategies as st`
    @staticmethod
    def integers(min_value, max_value):
        return _Integers(min_value, max_value)


def settings(max_examples=20, **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn

    return deco


def given(**strategies):
    def deco(fn):
        def wrapper():
            examples = getattr(wrapper, "_max_examples", 20)
            rng = random.Random(_SEED)
            for _ in range(examples):
                case = {name: s.sample(rng) for name, s in strategies.items()}
                fn(**case)

        # Copy test identity by hand: functools.wraps would expose the
        # wrapped signature and make pytest treat the sampled parameters
        # as fixtures.
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper

    return deco
