"""AOT pipeline tests: lowering produces loadable HLO text + manifest."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp

from compile import aot, model


def test_to_hlo_text_roundtrips_through_xla_parser():
    lowered = jax.jit(model.digits_linear_float).lower(
        jax.ShapeDtypeStruct((4, 784), jnp.float32),
        jax.ShapeDtypeStruct((784, 10), jnp.float32),
        jax.ShapeDtypeStruct((10,), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text and "f32[4,784]" in text


def test_quantized_model_lowers():
    specs = aot.model_specs(8)
    name, fn, arg_specs, signature = specs[0]
    assert name == "digits_linear_b8"
    text = aot.to_hlo_text(jax.jit(fn).lower(*arg_specs))
    assert "ENTRY" in text
    assert len(signature) == len(arg_specs)


def test_cli_writes_artifacts_and_manifest(tmp_path):
    out = tmp_path / "artifacts"
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out), "--batches", "2"],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["format"] == "hlo-text"
    names = {a["name"] for a in manifest["artifacts"]}
    assert names == {"digits_linear_b2", "fashion_mlp_b2", "digits_linear_float_b2"}
    for a in manifest["artifacts"]:
        content = (out / a["file"]).read_text()
        assert content.startswith("HloModule"), a["file"]
        assert len(a["inputs"]) >= 3
