"""Kernel-vs-oracle correctness: the core L1 signal.

The Pallas kernels must agree elementwise with the pure-jnp oracle in
``ref.py`` across shapes, modes, bit widths, seeds and ranges (hypothesis
sweeps), and the rounding schemes must satisfy the paper's §II/§VII
statistical properties (unbiasedness, variance ordering).
"""

import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # offline environment: seeded-sampling fallback
    from _hypothesis_compat import given, settings, st

from compile.kernels import prng, ref
from compile.kernels.quant_matmul import quant_matmul_pallas, quantize_pallas

TOL = 2e-6  # one-ulp-ish slack at the [-1, 1] scale


def rand(shape, lo, hi, seed):
    rng = np.random.default_rng(seed)
    return rng.uniform(lo, hi, shape).astype(np.float32)


# ---------------------------------------------------------------- prng


def test_hash_deterministic_and_sensitive():
    c = jnp.arange(1000, dtype=jnp.uint32)
    a = prng.hash_u32(jnp.uint32(1), c)
    b = prng.hash_u32(jnp.uint32(1), c)
    assert (a == b).all()
    c2 = prng.hash_u32(jnp.uint32(2), c)
    assert (a != c2).mean() > 0.99


def test_uniform01_range_and_mean():
    c = jnp.arange(200_000, dtype=jnp.uint32)
    u = prng.uniform01(jnp.uint32(3), c)
    assert float(u.min()) >= 0.0 and float(u.max()) < 1.0
    assert abs(float(u.mean()) - 0.5) < 0.005
    # Rough uniformity: decile counts within 5% of each other.
    hist, _ = np.histogram(np.asarray(u), bins=10, range=(0, 1))
    assert hist.max() - hist.min() < 0.05 * len(c) / 10 * 10


# ------------------------------------------------- quantize: oracle match


@settings(max_examples=40, deadline=None)
@given(
    rows=st.integers(1, 130),
    cols=st.integers(1, 40),
    k=st.integers(1, 8),
    mode=st.integers(0, 2),
    seed=st.integers(0, 2**32 - 1),
)
def test_quantize_pallas_matches_ref(rows, cols, k, mode, seed):
    x = rand((rows, cols), -1.0, 1.0, seed % 1000)
    got = quantize_pallas(jnp.array(x), k, mode, seed, -1.0, 1.0, block_rows=64)
    want = ref.quantize_once_ref(
        jnp.array(x), jnp.int32(k), jnp.int32(mode), jnp.uint32(seed), -1.0, 1.0
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=TOL)


@settings(max_examples=20, deadline=None)
@given(
    p=st.integers(1, 80),
    q=st.integers(1, 50),
    r=st.integers(1, 20),
    k=st.integers(1, 8),
    mode=st.integers(0, 2),
    seed=st.integers(0, 2**32 - 1),
)
def test_quant_matmul_pallas_matches_ref(p, q, r, k, mode, seed):
    x = rand((p, q), 0.0, 1.0, seed % 997)
    w = rand((q, r), -1.0, 1.0, (seed + 1) % 997)
    w_hat = ref.quantize_once_ref(
        jnp.array(w), jnp.int32(k), jnp.int32(0), jnp.uint32(5), -1.0, 1.0
    )
    got = quant_matmul_pallas(jnp.array(x), w_hat, k, mode, seed, -1.0, 1.0, block_rows=32)
    x_hat = ref.quantize_once_ref(
        jnp.array(x), jnp.int32(k), jnp.int32(mode), jnp.uint32(seed), -1.0, 1.0
    )
    want = jnp.dot(x_hat, w_hat, preferred_element_type=jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-5)


def test_quantize_respects_runtime_range():
    x = rand((16, 8), 0.0, 4.0, 1)
    got = quantize_pallas(jnp.array(x), 8, 0, 0, 0.0, 4.0)
    np.testing.assert_allclose(np.asarray(got), x, atol=4.0 / 255 / 2 + 1e-6)


# --------------------------------------------- statistical properties


def test_quantizer_levels_exact_at_high_k():
    x = rand((64, 16), -1.0, 1.0, 2)
    out = quantize_pallas(jnp.array(x), 8, 0, 0, -1.0, 1.0)
    err = np.abs(np.asarray(out) - x)
    assert err.max() <= (2.0 / 255) / 2 + 1e-6


@pytest.mark.parametrize("mode", [ref.MODE_STOCHASTIC, ref.MODE_DITHER])
def test_unbiased_modes_have_zero_mean_error(mode):
    x = np.full((1, 256), 0.3, dtype=np.float32)
    outs = []
    for seed in range(200):
        out = quantize_pallas(jnp.array(x), 1, mode, seed, 0.0, 1.0)
        outs.append(np.asarray(out).mean())
    mean = float(np.mean(outs))
    assert abs(mean - 0.3) < 0.01, mean


def test_deterministic_mode_is_biased_at_k1():
    # k=1: round(0.3 * 1) = 0 everywhere -> mean error 0.3 (the §VII
    # information-loss regime).
    x = np.full((4, 64), 0.3, dtype=np.float32)
    out = quantize_pallas(jnp.array(x), 1, ref.MODE_DETERMINISTIC, 0, 0.0, 1.0)
    assert float(np.abs(np.asarray(out)).max()) == 0.0


def test_dither_variance_below_stochastic():
    # Per-matrix mean of the quantized values: dither's deterministic
    # component cancels most of the variance (§II-D vs §II-A).
    x = np.full((1, 1024), 0.37, dtype=np.float32)

    def spread(mode):
        means = [
            float(np.asarray(quantize_pallas(jnp.array(x), 1, mode, s, 0.0, 1.0)).mean())
            for s in range(100)
        ]
        return np.var(means)

    v_sto = spread(ref.MODE_STOCHASTIC)
    v_dit = spread(ref.MODE_DITHER)
    assert v_dit < v_sto / 2, (v_dit, v_sto)


def test_dither_bit_branch_consistency():
    # Exact rationals m/N are represented deterministically (delta = 0).
    n = 64
    for m in (0, 8, 16, 32, 33, 63, 64):
        frac = jnp.full((128,), m / n, dtype=jnp.float32)
        pos = jnp.arange(128, dtype=jnp.uint32) % n
        u = prng.uniform01(jnp.uint32(9), jnp.arange(128, dtype=jnp.uint32))
        bits = ref.dither_bit(frac, pos, u, n)
        got = int(bits.sum())
        want = int((np.asarray(pos) < m).sum())
        assert got == want, (m, got, want)
