"""L2 model tests: shapes, float-reference agreement at high k, and the
paper's rounding-mode ordering on a synthetic classification task.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def make_linear(seed=0):
    rng = np.random.default_rng(seed)
    w = rng.uniform(-1, 1, (784, 10)).astype(np.float32)
    b = rng.uniform(-0.1, 0.1, (10,)).astype(np.float32)
    x = rng.uniform(0, 1, (32, 784)).astype(np.float32)
    return jnp.array(x), jnp.array(w), jnp.array(b)


def make_mlp(seed=0):
    rng = np.random.default_rng(seed)
    def u(*s, lim=1.0):
        return jnp.array(rng.uniform(-lim, lim, s).astype(np.float32))
    x = jnp.array(rng.uniform(0, 1, (16, 784)).astype(np.float32))
    return (
        x,
        u(784, 128), u(128, lim=0.1),
        u(128, 64), u(64, lim=0.1),
        u(64, 10), u(10, lim=0.1),
    )


def test_digits_linear_shapes():
    x, w, b = make_linear()
    out = model.digits_linear_forward(x, w, b, jnp.int32(8), jnp.int32(2), jnp.uint32(1))
    assert out.shape == (32, 10)
    assert out.dtype == jnp.float32


def test_digits_linear_high_k_matches_float():
    x, w, b = make_linear()
    out = model.digits_linear_forward(x, w, b, jnp.int32(16), jnp.int32(0), jnp.uint32(1))
    want = model.digits_linear_float(x, w, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=0.05, rtol=1e-3)
    # Argmax (the classification decision) should agree almost everywhere.
    agree = (np.argmax(np.asarray(out), 1) == np.argmax(np.asarray(want), 1)).mean()
    assert agree > 0.9


def test_fashion_mlp_shapes_and_finite():
    args = make_mlp()
    out = model.fashion_mlp_forward(
        *args,
        jnp.int32(8), jnp.int32(2), jnp.uint32(3),
        jnp.float32(20.0), jnp.float32(20.0),
    )
    assert out.shape == (16, 10)
    assert np.isfinite(np.asarray(out)).all()


def test_fashion_mlp_high_k_matches_float():
    args = make_mlp()
    out = model.fashion_mlp_forward(
        *args,
        jnp.int32(16), jnp.int32(0), jnp.uint32(3),
        jnp.float32(40.0), jnp.float32(40.0),
    )
    want = model.fashion_mlp_float(*args)
    agree = (np.argmax(np.asarray(out), 1) == np.argmax(np.asarray(want), 1)).mean()
    assert agree > 0.85, agree


@pytest.mark.parametrize("mode", [ref.MODE_STOCHASTIC, ref.MODE_DITHER])
def test_unbiased_modes_track_float_in_expectation(mode):
    x, w, b = make_linear(7)
    want = np.asarray(model.digits_linear_float(x, w, b))
    acc = np.zeros_like(want)
    trials = 30
    for s in range(trials):
        out = model.digits_linear_forward(
            x, w, b, jnp.int32(2), jnp.int32(mode), jnp.uint32(s)
        )
        acc += np.asarray(out) / trials
    # The trial-mean at k=2 approaches the float output; a single
    # deterministic rounding at k=2 does not.
    mean_err = np.abs(acc - want).mean()
    det = np.asarray(
        model.digits_linear_forward(x, w, b, jnp.int32(2), jnp.int32(0), jnp.uint32(0))
    )
    det_err = np.abs(det - want).mean()
    assert mean_err < det_err / 2, (mean_err, det_err)


def test_seed_changes_output_for_stochastic_modes():
    x, w, b = make_linear(9)
    a = model.digits_linear_forward(x, w, b, jnp.int32(2), jnp.int32(2), jnp.uint32(1))
    c = model.digits_linear_forward(x, w, b, jnp.int32(2), jnp.int32(2), jnp.uint32(2))
    assert not np.allclose(np.asarray(a), np.asarray(c))
    # Deterministic mode ignores the seed.
    d1 = model.digits_linear_forward(x, w, b, jnp.int32(2), jnp.int32(0), jnp.uint32(1))
    d2 = model.digits_linear_forward(x, w, b, jnp.int32(2), jnp.int32(0), jnp.uint32(2))
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))
