"""Pytest path setup: make `compile` importable when pytest runs from the
repository root (the Makefile runs from python/, the final validation
command from the root — support both)."""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
