//! Rounding-scheme sweep: scalar dither rounding in action, then the Fig-8
//! style matmul error comparison across bit widths.
//!
//! Run: `cargo run --release --example rounding_sweep [-- --dim 64 --pairs 5]`

use dither::linalg::{frobenius_error, quant_matmul, Matrix, QuantMatmulConfig, Variant};
use dither::rounding::{SchemeId, ScalarRounder};
use dither::util::cli::Args;
use dither::util::rng::Xoshiro256pp;

fn main() {
    let args = Args::from_env();
    let dim = args.parse_or("dim", 64usize);
    let pairs = args.parse_or("pairs", 5usize);

    // 1. Scalar rounding: round the same α repeatedly and watch the
    //    running mean converge (dither: ~1/N; stochastic: ~1/sqrt(N)).
    let alpha = 2.3137;
    println!("Rounding α = {alpha} repeatedly (running mean of the outputs):\n");
    println!("  {:>8} {:>14} {:>14} {:>14}", "#rounds", "deterministic", "stochastic", "dither");
    let mut rounders: Vec<ScalarRounder> = SchemeId::PAPER
        .iter()
        .map(|&m| ScalarRounder::new(m, 64, 99))
        .collect();
    let mut sums = [0.0f64; 3];
    let mut count = 0u64;
    for stop in [4u64, 16, 64, 256, 1024] {
        while count < stop {
            for (i, r) in rounders.iter_mut().enumerate() {
                sums[i] += r.round(alpha) as f64;
            }
            count += 1;
        }
        print!("  {count:>8}");
        for s in sums {
            print!(" {:>14.5}", s / count as f64);
        }
        println!();
    }
    println!("\n  (true value {alpha}; dither converges fastest — §VII)\n");

    // 2. Fig-8 style: k-bit quantized matmul error for entries in [0, 0.5).
    println!(
        "Quantized {dim}x{dim} matmul Frobenius error e_f (entries in [0,0.5), {pairs} pairs):\n"
    );
    println!("  {:>3} {:>14} {:>14} {:>14}", "k", "deterministic", "dither", "stochastic");
    for k in 1..=8u32 {
        let mut errs = [0.0f64; 3];
        for p in 0..pairs {
            let mut rng = Xoshiro256pp::new(1000 + p as u64);
            let a = Matrix::random_uniform(dim, dim, 0.0, 0.5, &mut rng);
            let b = Matrix::random_uniform(dim, dim, 0.0, 0.5, &mut rng);
            let c = a.matmul(&b);
            for (i, &mode) in SchemeId::PAPER.iter().enumerate() {
                let cfg = QuantMatmulConfig::unit(k, mode, Variant::PerPartial, p as u64);
                errs[i] += frobenius_error(&c, &quant_matmul(&a, &b, &cfg)) / pairs as f64;
            }
        }
        println!(
            "  {k:>3} {:>14.4} {:>14.4} {:>14.4}",
            errs[0], errs[1], errs[2]
        );
    }
    println!("\n  Small k: dither/stochastic win (unbiased). Large k: traditional");
    println!("  rounding's half-step determinism wins — the paper's threshold k̃.");
}
