//! Quickstart: the bitstream computing API in five minutes.
//!
//! Encodes real numbers as pulse sequences under the three schemes,
//! multiplies and averages them, and prints the accuracy comparison that
//! motivates the paper.
//!
//! Run: `cargo run --release --example quickstart`

use dither::bitstream::{
    average, evaluate, multiply, represent, EvalConfig, Op, Scheme,
};
use dither::util::rng::Xoshiro256pp;

fn main() {
    let mut rng = Xoshiro256pp::new(42);
    let (x, y) = (0.3721, 0.8164);
    let n = 256;

    println!("Representing x = {x} with N = {n} pulses\n");
    for scheme in Scheme::ALL {
        let est = represent(scheme, x, n, &mut rng);
        println!(
            "  {:<14} X_s = {est:.5}   error {:+.5}",
            scheme.name(),
            est - x
        );
    }

    println!("\nMultiplying x*y = {:.5} (bitwise AND of the sequences)\n", x * y);
    for scheme in Scheme::ALL {
        let est = multiply(scheme, x, y, n, &mut rng);
        println!(
            "  {:<14} Z_s = {est:.5}   error {:+.5}",
            scheme.name(),
            est - x * y
        );
    }

    println!(
        "\nAveraging (x+y)/2 = {:.5} (MUX with a control sequence)\n",
        (x + y) / 2.0
    );
    for scheme in Scheme::ALL {
        let est = average(scheme, x, y, n, &mut rng);
        println!(
            "  {:<14} U_s = {est:.5}   error {:+.5}",
            scheme.name(),
            est - (x + y) / 2.0
        );
    }

    // The paper's headline: dither computing gets the deterministic
    // variant's O(1/N²) EMSE *and* stochastic computing's zero bias.
    println!("\nEMSE for representing x ~ U[0,1] (100 pairs x 100 trials):\n");
    let cfg = EvalConfig {
        pairs: 100,
        trials: 100,
        seed: 7,
    };
    let pairs = cfg.draw_pairs();
    println!("  {:>6} {:>14} {:>14} {:>14}", "N", "stochastic", "determ.", "dither");
    for n in [16usize, 64, 256] {
        let row: Vec<f64> = Scheme::ALL
            .iter()
            .map(|&s| evaluate(s, Op::Represent, n, &pairs, &cfg).emse)
            .collect();
        println!(
            "  {n:>6} {:>14.3e} {:>14.3e} {:>14.3e}",
            row[0], row[1], row[2]
        );
    }
    println!("\nstochastic falls ~1/N; deterministic & dither fall ~1/N².");
    println!("dither is additionally unbiased — the best of both (Table I).");
}
