//! End-to-end driver (DESIGN.md §E2E): exercises every layer of the stack
//! on a real small workload.
//!
//! 1. Generates the synthetic digits dataset (or real MNIST if present).
//! 2. Trains the 1-layer softmax classifier with the pure-Rust SGD trainer,
//!    logging the loss curve.
//! 3. Sweeps quantized inference accuracy over k for the three rounding
//!    schemes (the paper's Fig 9/13 shape) using the Rust engines.
//! 4. Serves batched requests through the L3 serving engine (the model
//!    zoo + quantized forward pass the sharded server runs), reporting
//!    accuracy, latency and throughput.
//!
//! Run: `cargo run --release --example mnist_e2e`
//! Results recorded in EXPERIMENTS.md §End-to-end.

use dither::coordinator::Engine;
use dither::data::{Dataset, Task};
use dither::linalg::Variant;
use dither::nn::{quantized_accuracy, ActivationRanges, Mlp, QuantInferenceConfig};
use dither::rounding::SchemeId;
use dither::train::{train, TrainConfig};
use dither::util::error::Result;
use dither::util::rng::Xoshiro256pp;
use std::time::Instant;

fn main() -> Result<()> {
    // ---- 1. data -------------------------------------------------------
    let (train_set, test_set, source) =
        Dataset::load_or_synthesize(Task::Digits, 4000, 1000, 0xE2E);
    println!(
        "dataset: {} ({} train / {} test, classes {:?})",
        source,
        train_set.len(),
        test_set.len(),
        train_set.class_histogram()
    );

    // ---- 2. train ------------------------------------------------------
    let mut rng = Xoshiro256pp::new(0xE2E);
    let mut mlp = Mlp::single_layer(784, 10, &mut rng);
    let cfg = TrainConfig {
        epochs: 10,
        batch_size: 64,
        lr: 0.15,
        momentum: 0.9,
        seed: 0xE2E,
        verbose: false,
    };
    println!("\ntraining 1-layer softmax (784x10) with SGD+momentum:");
    let t = Instant::now();
    let history = train(&mut mlp, &train_set, &cfg);
    for h in &history {
        println!("  epoch {:>2}  loss {:.4}  train acc {:.4}", h.epoch, h.loss, h.accuracy);
    }
    mlp.normalize_weights();
    let float_acc = mlp.accuracy(&test_set.images, &test_set.labels);
    println!(
        "trained in {:.1}s; float test accuracy {:.4}",
        t.elapsed().as_secs_f64(),
        float_acc
    );

    // ---- 3. quantized inference sweep (native Rust engines) -------------
    println!("\nquantized accuracy vs k (separate placement, 5 trials):\n");
    println!("  {:>3} {:>14} {:>14} {:>14}", "k", "deterministic", "dither", "stochastic");
    let ranges = ActivationRanges::calibrate(&mlp, &test_set.images);
    for k in 1..=8u32 {
        let mut row = Vec::new();
        for mode in SchemeId::PAPER {
            let trials = if mode == SchemeId::Deterministic { 1 } else { 5 };
            let mut acc = 0.0;
            for t in 0..trials {
                let qcfg = QuantInferenceConfig {
                    bits: k,
                    mode,
                    variant: Variant::Separate,
                    seed: 0x5EED ^ (t << 16) ^ k as u64,
                };
                acc += quantized_accuracy(&mlp, &test_set.images, &test_set.labels, &ranges, &qcfg)
                    / trials as f64;
            }
            row.push(acc);
        }
        println!("  {k:>3} {:>14.4} {:>14.4} {:>14.4}", row[0], row[1], row[2]);
    }

    // ---- 4. serve through the L3 engine ---------------------------------
    println!("\nserving through the L3 engine (model zoo + quantized forward):");
    let engine = Engine::new(2000, 0xE2E);
    let batch: Vec<&[f64]> = (0..256.min(test_set.len()))
        .map(|i| test_set.images.row(i))
        .collect();
    // Warmup (first call may fault in the zoo weights).
    let _ = engine.infer_batch("digits_linear", 4, SchemeId::Dither, &batch[..1])?;
    let t = Instant::now();
    let outputs = engine.infer_batch("digits_linear", 4, SchemeId::Dither, &batch)?;
    let elapsed = t.elapsed().as_secs_f64();
    let correct = outputs
        .iter()
        .zip(&test_set.labels)
        .filter(|(o, &l)| o.pred == l)
        .count();
    println!(
        "  {} requests in {:.1} ms  ({:.0} req/s, {:.2} ms/req batched)",
        batch.len(),
        elapsed * 1e3,
        batch.len() as f64 / elapsed,
        elapsed * 1e3 / batch.len() as f64
    );
    println!(
        "  serving-path accuracy @ k=4 dither: {:.4} (engine model, batch {})",
        correct as f64 / batch.len() as f64,
        batch.len()
    );
    println!("\nall layers compose: data -> SGD -> quantized engines -> serving engine ✓");
    Ok(())
}
