//! Client for the dither inference server: sends a handful of requests with
//! different rounding configurations over the newline-JSON protocol and
//! prints the responses plus the server's metrics snapshot.
//!
//! Start the server first:  `dither serve --addr 127.0.0.1:7878`
//! Then: `cargo run --release --example serve_client [-- --addr 127.0.0.1:7878]`

use dither::coordinator::format_request;
use dither::data::{Dataset, Task};
use dither::rounding::SchemeId;
use dither::util::cli::Args;
use dither::util::error::Result;
use dither::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

fn main() -> Result<()> {
    let args = Args::from_env();
    let addr = args.str_or("addr", "127.0.0.1:7878");
    let stream = TcpStream::connect(&addr)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;

    let ds = Dataset::synthesize(Task::Digits, 8, 0xC11E);
    let mut line = String::new();

    // Ping.
    writeln!(writer, "{{\"cmd\":\"ping\"}}")?;
    reader.read_line(&mut line)?;
    print!("ping -> {line}");

    // Feature handshake: the server advertises the pipelined protocol
    // and its per-connection in-flight window.
    writeln!(writer, "{{\"cmd\":\"hello\"}}")?;
    line.clear();
    reader.read_line(&mut line)?;
    print!("hello -> {line}");

    // A/B the rounding schemes on the same images.
    for (id, mode, k) in [
        (1u64, SchemeId::Dither, 2u32),
        (2, SchemeId::Stochastic, 2),
        (3, SchemeId::Deterministic, 2),
        (4, SchemeId::Dither, 8),
    ] {
        let scheme = mode.wire_name();
        let img = ds.images.row((id as usize - 1) % ds.len());
        writeln!(writer, "{}", format_request(id, "digits_linear", k, mode, img))?;
        line.clear();
        reader.read_line(&mut line)?;
        let resp = Json::parse(line.trim()).unwrap();
        println!(
            "id={id} scheme={scheme:<14} k={k}  pred={} latency={}us batch={} shard={}",
            resp.get("pred").and_then(Json::as_f64).unwrap_or(-1.0),
            resp.get("latency_us").and_then(Json::as_f64).unwrap_or(-1.0),
            resp.get("batch").and_then(Json::as_f64).unwrap_or(-1.0),
            resp.get("shard").and_then(Json::as_f64).unwrap_or(-1.0),
        );
    }

    // Metrics.
    writeln!(writer, "{{\"cmd\":\"stats\"}}")?;
    line.clear();
    reader.read_line(&mut line)?;
    println!("\nserver stats: {}", line.trim());
    Ok(())
}
