//! Load generator + correctness checker for the sharded inference server.
//!
//! Drives the server with concurrent client connections issuing a mixed
//! workload — both model families, several bit widths, and every
//! registered rounding scheme interleaved on every connection — then
//! verifies each reply:
//!
//! * structural: the reply echoes the request id and scheme, carries a
//!   10-class row of finite logits, `pred` is the argmax, and `shard` is
//!   constant for the connection;
//! * exact: deterministic-scheme logits must match a local reference
//!   [`Engine`] bit-for-bit (deterministic rounding is stateless, so the
//!   serving batch composition cannot change per-row results);
//! * bounded: stochastic/dither logits must lie within the quantization
//!   error budget of the deterministic reference (each rounded factor
//!   moves by at most one quantizer step).
//!
//! Exits nonzero if any reply is incorrect.
//!
//! Two driving modes: lockstep (default — one request, then one reply per
//! connection) and `--pipelined`, which performs the `hello` feature
//! handshake and then keeps `--inflight` requests in flight per
//! connection, matching out-of-order replies back to their requests by
//! the echoed id and verifying each against the same reference engine.
//!
//! `--expect-traces` asserts the observability contract end to end: the
//! server (or proxy) must have been started with `--trace-rate 1.0` and a
//! `--trace-buffer` at least the request count, and after the run every
//! completed request's timeline must be retrievable via `{"cmd":"trace"}`.
//! `--scrape-metrics` scrapes `{"cmd":"metrics"}` and checks the
//! Prometheus exposition is well-formed (plus, on a traced run, that at
//! least one per-stage span histogram is populated).
//!
//! `--expect-auto-slo` closes the measured-cost SLO loop end to end:
//! after warming one `(model, k)` recent-latency window with concrete
//! traffic, latency-only auto requests must resolve, and dual-budget
//! (`max_mse` + `max_latency_us`) autos must come back tagged
//! `"measured": true` — proof the server priced them against live
//! latency windows rather than the static cost walk.
//!
//! `--watch` opens a live `{"cmd":"watch"}` subscription before driving
//! traffic and reports what it streamed; `--expect-events` additionally
//! fails the run unless the stream was well-formed (strictly increasing
//! sequence numbers) and carried at least one `alert_fired` event —
//! pair it with a server started under a breachable SLO
//! (`--slo-p99-us 1 --slo-eval-ms 100`).
//!
//! `--proxy` drives a cluster front tier instead of a single server: the
//! per-connection shard-stability check is skipped (the proxy routes each
//! request by its configuration key, so one connection's replies come
//! from many backend shards), and with `--backends a,b,...` the run ends
//! by scraping every backend directly and asserting the proxy's merged
//! counters and fidelity samples equal the per-backend sums.
//!
//! Start the server first: `cargo run --release -- serve`
//! Then:
//! `cargo run --release --example load_gen -- --requests 1200 --clients 8`
//! or pipelined:
//! `cargo run --release --example load_gen -- --pipelined --inflight 32`
//!
//! Run both from the same directory (the reference engine must see the
//! same cached zoo weights; with matching `--train-n`/`--seed` it retrains
//! identical weights even without the cache).

use dither::coordinator::{
    format_request, format_request_auto_slo, format_watch, parse_watch_ack, wait_ready, Engine,
    WatchQuery,
};
use dither::data::{Dataset, Task};
use dither::obs::{parse_event_line, Event, EventKind};
use dither::rounding::SchemeId;
use dither::util::cli::Args;
use dither::util::error::Result;
use dither::util::json::Json;
use std::collections::{HashSet, VecDeque};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Every registered scheme: cycling through this drives at least one
/// request per zoo member, so a smoke run covers the whole registry.
const SCHEMES: [SchemeId; SchemeId::COUNT] = SchemeId::ALL;
const KS: [u32; 3] = [2, 4, 8];

/// Logit error budget of one quantized matmul at width `k` against the
/// exact product: `q` additions, each factor within one step of [-1, 1]
/// data. Deterministic and unbiased schemes are each within the budget, so
/// their mutual distance is within twice that (per layer; the MLP's later
/// layers use wider calibrated ranges, folded in via `range_scale`).
fn logit_budget(k: u32, q: usize, range_scale: f64) -> f64 {
    let step = 2.0 / ((1u64 << k) - 1) as f64 * range_scale;
    q as f64 * (2.0 * step + step * step)
}

struct Workload {
    digits: Dataset,
    fashion: Dataset,
}

struct Case<'a> {
    model: &'static str,
    k: u32,
    mode: SchemeId,
    pixels: &'a [f64],
}

impl Workload {
    fn case(&self, i: usize) -> Case<'_> {
        let mode = SCHEMES[i % SCHEMES.len()];
        let k = KS[(i / SCHEMES.len()) % KS.len()];
        let (model, ds) = if i % 10 < 7 {
            ("digits_linear", &self.digits)
        } else {
            ("fashion_mlp", &self.fashion)
        };
        Case {
            model,
            k,
            mode,
            pixels: ds.images.row(i % ds.len()),
        }
    }
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let addr = args.str_or("addr", "127.0.0.1:7878");
    let requests = args.parse_or("requests", 1200usize);
    let clients = args.parse_or("clients", 8usize).max(1);
    let train_n = args.parse_or("train-n", 2000usize);
    let seed = args.parse_or("seed", 7u64);
    let expect_fidelity = args.flag("expect-fidelity");
    let expect_traces = args.flag("expect-traces");
    let expect_auto_slo = args.flag("expect-auto-slo");
    let scrape_metrics = args.flag("scrape-metrics");
    let expect_events = args.flag("expect-events");
    let watch = args.flag("watch") || expect_events;
    let pipelined = args.flag("pipelined");
    let proxy = args.flag("proxy");
    let backends: Vec<String> = args.parse_list_or("backends", Vec::new());
    let inflight = args.parse_or("inflight", 32usize).max(1);

    // The server may still be training its zoo (CI starts both at once).
    if !wait_ready(&addr, Duration::from_secs(300)) {
        eprintln!("FAIL: server at {addr} never became ready");
        std::process::exit(1);
    }

    // The watcher subscribes before any traffic so SLO alerts fired by
    // the run itself are guaranteed to be in-stream (delivery starts at
    // the next published event; there is no replay).
    let watcher = if watch { Some(start_watcher(&addr)?) } else { None };

    println!("load_gen: building reference engine (train_n={train_n}, seed={seed}) ...");
    let reference = Engine::new(train_n, seed);
    let workload = Workload {
        digits: Dataset::synthesize(Task::Digits, 64, 0x10AD),
        fashion: Dataset::synthesize(Task::Fashion, 64, 0x10AE),
    };

    let violations: Mutex<Vec<String>> = Mutex::new(Vec::new());
    let completed = AtomicU64::new(0);
    let completed_ids: Mutex<HashSet<u64>> = Mutex::new(HashSet::new());
    let overloaded_retries = AtomicU64::new(0);
    let per_client = requests.div_ceil(clients);

    let mode = if pipelined {
        format!("pipelined, {inflight} in flight per connection")
    } else {
        "lockstep".to_string()
    };
    println!(
        "load_gen: driving {addr} with {clients} clients x {per_client} requests \
         (mixed models/k/schemes, {mode}) ..."
    );
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for c in 0..clients {
            let reference = &reference;
            let workload = &workload;
            let violations = &violations;
            let completed = &completed;
            let completed_ids = &completed_ids;
            let overloaded_retries = &overloaded_retries;
            let addr = addr.clone();
            scope.spawn(move || {
                let run = if pipelined {
                    run_client_pipelined(
                        &addr,
                        c,
                        per_client,
                        inflight,
                        workload,
                        reference,
                        violations,
                        completed,
                        completed_ids,
                        overloaded_retries,
                        proxy,
                    )
                } else {
                    run_client(
                        &addr,
                        c,
                        per_client,
                        workload,
                        reference,
                        violations,
                        completed,
                        completed_ids,
                        overloaded_retries,
                        proxy,
                    )
                };
                if let Err(e) = run {
                    violations
                        .lock()
                        .unwrap()
                        .push(format!("client {c}: transport error: {e}"));
                }
            });
        }
    });
    let elapsed = t0.elapsed().as_secs_f64();
    let done = completed.load(Ordering::Relaxed);

    // Scrape the merged per-shard stats.
    let stats = fetch_stats(&addr)?;
    let shards = stats.get("shards").and_then(Json::as_f64).unwrap_or(0.0) as usize;
    let per_shard = stats
        .get("per_shard_requests")
        .and_then(Json::as_f64_vec)
        .unwrap_or_default();
    let busy = per_shard.iter().filter(|&&r| r > 0.0).count();

    println!(
        "\nload_gen: {done} requests in {elapsed:.2}s ({:.0} req/s), \
         {} overload retries",
        done as f64 / elapsed,
        overloaded_retries.load(Ordering::Relaxed)
    );
    println!("server shards: {shards} ({busy} busy), per-shard requests: {per_shard:?}");

    let violations = violations.into_inner().unwrap();
    if done < requests as u64 {
        eprintln!("FAIL: only {done}/{requests} requests completed");
        std::process::exit(1);
    }
    if busy < shards.min(2) {
        eprintln!("FAIL: only {busy} of {shards} shards served traffic");
        std::process::exit(1);
    }
    if !violations.is_empty() {
        eprintln!("\nFAIL: {} incorrect replies:", violations.len());
        for v in violations.iter().take(20) {
            eprintln!("  {v}");
        }
        std::process::exit(1);
    }
    // --expect-fidelity: the server was started with a nonzero
    // --shadow-rate, so the merged stats must report populated
    // per-(model, scheme, k) shadow-sampling estimates.
    if expect_fidelity {
        let entries = stats
            .get("fidelity")
            .and_then(Json::as_arr)
            .map(<[Json]>::to_vec)
            .unwrap_or_default();
        let samples: f64 = entries
            .iter()
            .filter_map(|e| e.get("samples").and_then(Json::as_f64))
            .sum();
        if entries.is_empty() || samples <= 0.0 {
            eprintln!(
                "FAIL: stats.fidelity is not populated ({} cells, {samples} samples) — \
                 was the server started with --shadow-rate > 0?",
                entries.len()
            );
            std::process::exit(1);
        }
        println!(
            "fidelity: {} (model, scheme, k) cells populated from {samples} shadow samples",
            entries.len()
        );
    }
    // --proxy --backends a,b,...: the front tier's merged stats must be
    // exactly the sum of the per-backend scrapes — counters and shadow
    // samples alike (the load is quiescent by now, so sums are stable).
    if proxy && !backends.is_empty() {
        let merged_requests = stats.get("requests").and_then(Json::as_f64).unwrap_or(-1.0);
        let merged_samples = fidelity_samples(&stats);
        let mut sum_requests = 0.0;
        let mut sum_samples = 0.0;
        for b in &backends {
            let s = fetch_stats(b)?;
            sum_requests += s.get("requests").and_then(Json::as_f64).unwrap_or(0.0);
            sum_samples += fidelity_samples(&s);
        }
        if merged_requests != sum_requests {
            eprintln!(
                "FAIL: proxy merged requests {merged_requests} != backend sum {sum_requests}"
            );
            std::process::exit(1);
        }
        if merged_samples != sum_samples {
            eprintln!(
                "FAIL: proxy merged fidelity samples {merged_samples} != backend sum {sum_samples}"
            );
            std::process::exit(1);
        }
        println!(
            "proxy merge: requests {merged_requests} and fidelity samples {merged_samples} \
             equal the {}-backend sums",
            backends.len()
        );
    }
    // --expect-traces: the server was started sampling everything
    // (--trace-rate 1.0) with a ring at least as large as the run, so
    // every completed request's timeline is still retrievable.
    if expect_traces {
        let dump = fetch_traces(&addr)?;
        let have: HashSet<u64> = dump
            .get("traces")
            .and_then(Json::as_arr)
            .map(|ts| {
                ts.iter()
                    .filter_map(|t| t.get("id").and_then(Json::as_f64).map(|v| v as u64))
                    .collect()
            })
            .unwrap_or_default();
        let want = completed_ids.lock().unwrap();
        let missing: Vec<u64> =
            want.iter().copied().filter(|id| !have.contains(id)).collect();
        if want.is_empty() || !missing.is_empty() {
            eprintln!(
                "FAIL: {} of {} completed requests have no retrievable trace \
                 (first missing ids: {:?}) — was the server started with \
                 --trace-rate 1.0 and --trace-buffer >= the request count?",
                missing.len(),
                want.len(),
                &missing[..missing.len().min(10)]
            );
            std::process::exit(1);
        }
        println!(
            "traces: all {} completed requests retrievable from the ring \
             ({} resident timelines)",
            want.len(),
            have.len()
        );
    }
    // --expect-auto-slo: the measured-cost SLO loop must be closed — see
    // the module doc. Runs after the main sweep so the recent-latency
    // windows are already rich with mixed traffic.
    if expect_auto_slo {
        if let Err(e) = run_auto_slo(&addr, &workload) {
            eprintln!("FAIL: auto-SLO loop: {e}");
            std::process::exit(1);
        }
    }
    // --scrape-metrics: the Prometheus surface must be well-formed text
    // exposition carrying the core serving families — and, on a traced
    // run, at least one populated per-stage span histogram.
    if scrape_metrics {
        let text = fetch_metrics(&addr)?;
        if let Err(e) = dither::trace::check_exposition(&text) {
            eprintln!("FAIL: metrics exposition is malformed: {e}");
            std::process::exit(1);
        }
        for family in ["dither_requests_total", "dither_latency_us_bucket"] {
            if !text.contains(family) {
                eprintln!("FAIL: metrics exposition lacks {family}");
                std::process::exit(1);
            }
        }
        if expect_traces && !text.contains("dither_stage_duration_us_bucket") {
            eprintln!("FAIL: a traced run must expose at least one stage histogram");
            std::process::exit(1);
        }
        println!(
            "metrics: well-formed Prometheus exposition ({} bytes)",
            text.len()
        );
    }
    // --watch / --expect-events: tear the subscription down and check
    // what it streamed. The alert may fire a tick or two after the last
    // request completes, so --expect-events waits bounded for it.
    if let Some(w) = watcher {
        if expect_events {
            let deadline = Instant::now() + Duration::from_secs(30);
            while Instant::now() < deadline {
                let fired = w
                    .events
                    .lock()
                    .unwrap()
                    .iter()
                    .any(|e| e.kind == EventKind::AlertFired);
                if fired {
                    break;
                }
                std::thread::sleep(Duration::from_millis(100));
            }
        }
        w.stop.store(true, Ordering::Relaxed);
        let _ = w.handle.join();
        let events = w.events.lock().unwrap();
        println!(
            "watch: subscription {} streamed {} events",
            w.watch_id,
            events.len()
        );
        if expect_events {
            if events.is_empty() {
                eprintln!(
                    "FAIL: --expect-events streamed nothing — was the server \
                     started with a breachable SLO (--slo-p99-us 1 --slo-eval-ms 100)?"
                );
                std::process::exit(1);
            }
            if !events.windows(2).all(|p| p[0].seq < p[1].seq) {
                eprintln!("FAIL: event stream sequence numbers are not strictly increasing");
                std::process::exit(1);
            }
            if !events.iter().any(|e| e.kind == EventKind::AlertFired) {
                eprintln!(
                    "FAIL: --expect-events requires an alert_fired event; kinds seen: {:?}",
                    events
                        .iter()
                        .map(|e| e.kind.wire_name())
                        .collect::<HashSet<_>>()
                );
                std::process::exit(1);
            }
            println!("watch: stream well-formed, SLO alert observed");
        }
    }
    println!("PASS: {done} mixed-scheme requests, zero incorrect replies");
    Ok(())
}

/// A live watch subscription: the subscribing connection's drain thread
/// plus the events it has collected so far.
struct Watcher {
    stop: Arc<AtomicBool>,
    events: Arc<Mutex<Vec<Event>>>,
    handle: std::thread::JoinHandle<()>,
    watch_id: u64,
}

/// Subscribe to everything the server (or proxy) journals and collect
/// the stream on a background thread until stopped.
fn start_watcher(addr: &str) -> Result<Watcher> {
    let stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(Duration::from_millis(250)))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    writeln!(writer, "{}", format_watch(&WatchQuery::default()))?;
    writer.flush()?;
    let mut line = String::new();
    let deadline = Instant::now() + Duration::from_secs(10);
    let watch_id = loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => return Err("watch connection closed before the ack".to_string().into()),
            Ok(_) => {
                break parse_watch_ack(line.trim())
                    .map_err(|e| format!("bad watch ack: {e}"))?
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                if Instant::now() > deadline {
                    return Err("watch ack timed out".to_string().into());
                }
                continue;
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e.into()),
        }
    };
    let stop = Arc::new(AtomicBool::new(false));
    let events = Arc::new(Mutex::new(Vec::new()));
    let (stop2, events2) = (stop.clone(), events.clone());
    let handle = std::thread::spawn(move || {
        let mut line = String::new();
        while !stop2.load(Ordering::Relaxed) {
            line.clear();
            match reader.read_line(&mut line) {
                Ok(0) => break,
                Ok(_) => {
                    if let Some((_, ev)) = parse_event_line(&line) {
                        events2.lock().unwrap().push(ev);
                    }
                }
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock
                            | std::io::ErrorKind::TimedOut
                            | std::io::ErrorKind::Interrupted
                    ) =>
                {
                    continue
                }
                Err(_) => break,
            }
        }
    });
    Ok(Watcher {
        stop,
        events,
        handle,
        watch_id,
    })
}

/// One lockstep request/reply exchange, parsed.
fn roundtrip(
    writer: &mut TcpStream,
    reader: &mut BufReader<TcpStream>,
    req: &str,
) -> Result<Json> {
    writeln!(writer, "{req}")?;
    writer.flush()?;
    let mut line = String::new();
    reader.read_line(&mut line)?;
    Ok(Json::parse(line.trim())?)
}

/// Drive the `--expect-auto-slo` contract on one lockstep connection:
/// warm the `(digits_linear, k=2)` dither latency window past the
/// controller's measured threshold, check a latency-only auto resolves,
/// then require 8 consecutive dual-budget autos tagged `"measured": true`
/// once the server's auto-view refresher has folded the warm windows.
fn run_auto_slo(addr: &str, workload: &Workload) -> Result<()> {
    const WARMUP: u64 = 64;
    let stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    for i in 0..WARMUP {
        let pixels = workload.digits.images.row(i as usize % workload.digits.len());
        let req = format_request(900_000 + i, "digits_linear", 2, SchemeId::Dither, pixels);
        let resp = roundtrip(&mut writer, &mut reader, &req)?;
        if resp.get("error").is_some() {
            return Err(format!("auto-slo warmup request failed: {resp}").into());
        }
    }
    let pixels = workload.digits.images.row(0);
    // A latency-only budget is a complete auto request on its own.
    let lat_only =
        format_request_auto_slo(900_100, "digits_linear", None, Some(5_000_000), pixels);
    let resp = roundtrip(&mut writer, &mut reader, &lat_only)?;
    if resp.get("error").is_some() || resp.get("auto").and_then(Json::as_bool) != Some(true) {
        return Err(format!("latency-only auto did not resolve: {resp}").into());
    }
    // Dual-budget autos: always structurally valid, and measured once the
    // refresher (50 ms cadence) has folded the warm windows.
    let deadline = Instant::now() + Duration::from_secs(30);
    let mut id = 900_200u64;
    let mut measured_streak = 0usize;
    while measured_streak < 8 {
        let req = format_request_auto_slo(
            id,
            "digits_linear",
            Some(1e9),
            Some(5_000_000),
            pixels,
        );
        id += 1;
        let resp = roundtrip(&mut writer, &mut reader, &req)?;
        if resp.get("error").is_some() || resp.get("auto").and_then(Json::as_bool) != Some(true)
        {
            return Err(format!("dual-budget auto failed: {resp}").into());
        }
        let scheme_ok = resp
            .get("scheme")
            .and_then(Json::as_str)
            .is_some_and(|s| s.parse::<SchemeId>().is_ok());
        let k = resp.get("k").and_then(Json::as_f64).unwrap_or(0.0);
        if !scheme_ok || !(1.0..=16.0).contains(&k) {
            return Err(format!("auto reply lacks a servable (scheme, k): {resp}").into());
        }
        if resp.get("measured").and_then(Json::as_bool) == Some(true) {
            measured_streak += 1;
        } else {
            measured_streak = 0;
            if Instant::now() > deadline {
                return Err("dual-budget autos never resolved from live measurements"
                    .to_string()
                    .into());
            }
            std::thread::sleep(Duration::from_millis(50));
        }
    }
    println!("auto-slo: latency-only autos resolve; 8 consecutive dual-budget autos measured");
    Ok(())
}

/// Scrape the full trace ring (`{"cmd":"trace"}`, no filters) as raw JSON
/// — through the proxy this is the stitched cross-process reply.
fn fetch_traces(addr: &str) -> Result<Json> {
    let stream = TcpStream::connect(addr)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    writeln!(writer, "{{\"cmd\":\"trace\"}}")?;
    writer.flush()?;
    let mut line = String::new();
    reader.read_line(&mut line)?;
    Ok(Json::parse(line.trim())?)
}

/// Scrape `{"cmd":"metrics"}` and unwrap the exposition text.
fn fetch_metrics(addr: &str) -> Result<String> {
    let stream = TcpStream::connect(addr)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    writeln!(writer, "{{\"cmd\":\"metrics\"}}")?;
    writer.flush()?;
    let mut line = String::new();
    reader.read_line(&mut line)?;
    dither::coordinator::parse_metrics_reply(line.trim())
        .map_err(|e| format!("bad metrics reply: {e}").into())
}

/// Total shadow samples across a stats reply's fidelity cells.
fn fidelity_samples(stats: &Json) -> f64 {
    stats
        .get("fidelity")
        .and_then(Json::as_arr)
        .map(|cells| {
            cells
                .iter()
                .filter_map(|c| c.get("samples").and_then(Json::as_f64))
                .sum()
        })
        .unwrap_or(0.0)
}

#[allow(clippy::too_many_arguments)]
fn run_client(
    addr: &str,
    client: usize,
    count: usize,
    workload: &Workload,
    reference: &Engine,
    violations: &Mutex<Vec<String>>,
    completed: &AtomicU64,
    completed_ids: &Mutex<HashSet<u64>>,
    overloaded_retries: &AtomicU64,
    proxy: bool,
) -> Result<()> {
    let stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut line = String::new();
    let mut conn_shard: Option<f64> = None;

    for j in 0..count {
        let case_idx = client * count + j;
        let case = workload.case(case_idx);
        let id = case_idx as u64 + 1;
        let req = format_request(id, case.model, case.k, case.mode, case.pixels);
        // Retry on overload (bounded-queue backpressure is correct
        // behaviour, not an incorrect reply).
        let resp = loop {
            writeln!(writer, "{req}")?;
            writer.flush()?;
            line.clear();
            reader.read_line(&mut line)?;
            let resp = Json::parse(line.trim())
                .map_err(|e| format!("client {client} req {id}: bad json: {e}"))?;
            if resp
                .get("overloaded")
                .and_then(Json::as_bool)
                .unwrap_or(false)
            {
                overloaded_retries.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(std::time::Duration::from_millis(5));
                continue;
            }
            break resp;
        };
        // Through a proxy, one connection's requests fan out to many
        // backends by key, so shard stability only holds per key — skip
        // the per-connection check.
        let mut scratch = None;
        let shard_slot = if proxy { &mut scratch } else { &mut conn_shard };
        if let Some(v) = check_reply(&case, id, &resp, shard_slot, reference) {
            violations.lock().unwrap().push(v);
        }
        completed.fetch_add(1, Ordering::Relaxed);
        completed_ids.lock().unwrap().insert(id);
    }
    Ok(())
}

/// Pipelined driver: keep `window` requests in flight on one connection,
/// matching each out-of-order reply back to the request it answers by the
/// echoed id and verifying its payload against the reference engine.
/// Overloaded replies (window or queue backpressure) requeue the request.
#[allow(clippy::too_many_arguments)]
fn run_client_pipelined(
    addr: &str,
    client: usize,
    count: usize,
    window: usize,
    workload: &Workload,
    reference: &Engine,
    violations: &Mutex<Vec<String>>,
    completed: &AtomicU64,
    completed_ids: &Mutex<HashSet<u64>>,
    overloaded_retries: &AtomicU64,
    proxy: bool,
) -> Result<()> {
    let stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut line = String::new();

    // Feature handshake: the server must advertise pipelining; its
    // per-connection window caps how much we keep in flight.
    writeln!(writer, "{{\"cmd\":\"hello\"}}")?;
    writer.flush()?;
    reader.read_line(&mut line)?;
    let hello = Json::parse(line.trim())
        .map_err(|e| format!("client {client}: bad hello reply: {e}"))?;
    let supports_pipelining = hello
        .get("features")
        .and_then(Json::as_arr)
        .is_some_and(|f| f.iter().any(|v| v.as_str() == Some("pipelined")));
    if !supports_pipelining {
        violations
            .lock()
            .unwrap()
            .push(format!("client {client}: server does not advertise pipelining: {line}"));
        return Ok(());
    }
    // Protocol v2: the hello must carry the registered-scheme list (and
    // the proxy's intersection of it across backends must be non-empty).
    let proto = hello.get("proto").and_then(Json::as_f64).unwrap_or(1.0);
    let schemes_advertised = hello
        .get("schemes")
        .and_then(Json::as_arr)
        .map_or(0, <[Json]>::len);
    if proto < 2.0 || schemes_advertised == 0 {
        violations.lock().unwrap().push(format!(
            "client {client}: hello must advertise proto >= 2 and a non-empty \
             scheme list: {line}"
        ));
        return Ok(());
    }
    let server_window = hello
        .get("max_inflight")
        .and_then(Json::as_f64)
        .unwrap_or(1.0) as usize;
    let window = window.min(server_window.max(1));

    let base = (client * count) as u64;
    let mut conn_shard: Option<f64> = None;
    let mut next = 0usize; // next fresh case offset
    let mut retry: VecDeque<usize> = VecDeque::new(); // overloaded, to resend
    let mut outstanding: HashSet<u64> = HashSet::new();
    let mut done = 0usize;
    while done < count {
        // Fill the window without waiting for replies.
        while outstanding.len() < window && (!retry.is_empty() || next < count) {
            let j = match retry.pop_front() {
                Some(j) => j,
                None => {
                    let j = next;
                    next += 1;
                    j
                }
            };
            let case = workload.case(client * count + j);
            let id = base + j as u64 + 1;
            writeln!(
                writer,
                "{}",
                format_request(id, case.model, case.k, case.mode, case.pixels)
            )?;
            outstanding.insert(id);
        }
        writer.flush()?;
        // Drain one reply — any order — and match it back by id.
        line.clear();
        reader.read_line(&mut line)?;
        let resp = Json::parse(line.trim())
            .map_err(|e| format!("client {client}: bad json: {e}"))?;
        let Some(id) = resp.get("id").and_then(Json::as_f64).map(|v| v as u64) else {
            violations
                .lock()
                .unwrap()
                .push(format!("client {client}: reply without id: {line}"));
            continue;
        };
        if !outstanding.remove(&id) {
            violations
                .lock()
                .unwrap()
                .push(format!("client {client}: unexpected or duplicate reply id {id}"));
            continue;
        }
        let j = (id - base - 1) as usize;
        if resp
            .get("overloaded")
            .and_then(Json::as_bool)
            .unwrap_or(false)
        {
            overloaded_retries.fetch_add(1, Ordering::Relaxed);
            retry.push_back(j);
            std::thread::sleep(Duration::from_millis(2));
            continue;
        }
        let case = workload.case(client * count + j);
        let mut scratch = None;
        let shard_slot = if proxy { &mut scratch } else { &mut conn_shard };
        if let Some(v) = check_reply(&case, id, &resp, shard_slot, reference) {
            violations.lock().unwrap().push(v);
        }
        done += 1;
        completed.fetch_add(1, Ordering::Relaxed);
        completed_ids.lock().unwrap().insert(id);
    }
    Ok(())
}

/// Verify one reply; returns a violation description if it is incorrect.
fn check_reply(
    case: &Case<'_>,
    id: u64,
    resp: &Json,
    conn_shard: &mut Option<f64>,
    reference: &Engine,
) -> Option<String> {
    let ctx = format!(
        "req {id} ({} k={} {})",
        case.model,
        case.k,
        case.mode.wire_name()
    );
    if let Some(err) = resp.get("error").and_then(Json::as_str) {
        return Some(format!("{ctx}: server error: {err}"));
    }
    if resp.get("id").and_then(Json::as_f64) != Some(id as f64) {
        return Some(format!("{ctx}: wrong id echo: {resp}"));
    }
    if resp.get("scheme").and_then(Json::as_str) != Some(case.mode.wire_name()) {
        return Some(format!("{ctx}: wrong scheme echo: {resp}"));
    }
    let shard = match resp.get("shard").and_then(Json::as_f64) {
        Some(s) => s,
        None => return Some(format!("{ctx}: missing 'shard': {resp}")),
    };
    match conn_shard {
        Some(s) if *s != shard => {
            return Some(format!("{ctx}: shard moved {s} -> {shard} mid-connection"))
        }
        Some(_) => {}
        None => *conn_shard = Some(shard),
    }
    let logits = match resp.get("logits").and_then(Json::as_f64_vec) {
        Some(l) if l.len() == 10 && l.iter().all(|v| v.is_finite()) => l,
        other => return Some(format!("{ctx}: bad logits {other:?}")),
    };
    let pred = match resp.get("pred").and_then(Json::as_f64) {
        Some(p) => p as usize,
        None => return Some(format!("{ctx}: missing 'pred': {resp}")),
    };
    let argmax = logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap_or(0);
    if pred != argmax {
        return Some(format!("{ctx}: pred {pred} != argmax {argmax}"));
    }

    // Compare against the local reference engine. Deterministic rounding
    // is stateless, so a single-row reference call reproduces the served
    // batch's per-row result exactly.
    let rows = [case.pixels];
    let expect = match reference.infer_batch(case.model, case.k, SchemeId::Deterministic, &rows)
    {
        Ok(mut out) if !out.is_empty() => out.remove(0),
        Ok(_) => return Some(format!("{ctx}: reference engine returned no output")),
        Err(e) => return Some(format!("{ctx}: reference engine failed: {e}")),
    };
    match case.mode {
        SchemeId::Deterministic => {
            if logits != expect.logits {
                return Some(format!(
                    "{ctx}: deterministic logits diverge from reference \
                     (got {:?}, want {:?})",
                    &logits[..3.min(logits.len())],
                    &expect.logits[..3]
                ));
            }
        }
        _ => {
            // The randomized family — plain SR, dither, and every zoo
            // scheme — rounds each factor to floor or ceiling, so one
            // quantizer step bounds the per-factor move. Loose but sound
            // bound for the single-layer model, whose quantizer ranges
            // are the paper's fixed [-1, 1]: both replies sit within one
            // quantization budget of the exact product. (The 3-layer
            // model's budget depends on calibrated hidden ranges, so
            // only the structural checks above apply to it.)
            if case.model == "digits_linear" {
                let bound = 2.0 * logit_budget(case.k, 784, 1.0);
                for (a, b) in logits.iter().zip(&expect.logits) {
                    if (a - b).abs() > bound {
                        return Some(format!(
                            "{ctx}: logit {a} vs deterministic {b} exceeds budget {bound:.3}"
                        ));
                    }
                }
            }
        }
    }
    None
}

fn fetch_stats(addr: &str) -> Result<Json> {
    let stream = TcpStream::connect(addr)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    writeln!(writer, "{{\"cmd\":\"stats\"}}")?;
    writer.flush()?;
    let mut line = String::new();
    reader.read_line(&mut line)?;
    Ok(Json::parse(line.trim())?)
}
